//! Live agent-DAG execution: walk a request through its
//! [`ExecutionPlan`] node bindings on the real serving stack — CPU/
//! tool/IO stages on the bounded [`HostPool`], LLM stages through the
//! admission → batcher → engine loop — exactly the graph the DAG
//! simulator (`cluster/dag.rs`) executes in modeled time.
//!
//! Split of responsibilities:
//!
//! * [`DagRuntime`] — static, derived once per installed plan: the
//!   topology ([`DagTopology`]), the engine inference units
//!   ([`crate::plan::instance::llm_units`]), the virtual pipeline fleet
//!   (expanded replicas with chassis, for per-role routing/accounting
//!   and cross-chassis edge-transfer modeling), and the time scale that
//!   maps planner-profiled latencies onto wall-clock sleeps.
//! * [`DagDispatch`] — the per-request bookkeeping the serving loop
//!   drives: dependency counts, ready-unit extraction, modeled transfer
//!   timers, per-stage spans, and failure isolation (a failing tool
//!   node terminates *its* request; every other request and the
//!   dispatcher keep running).
//!
//! The dispatcher returns [`LlmJob`]s for the serving loop to feed into
//! its continuous batcher, and receives [`UnitOutcome`]s back once the
//! engine has executed a batch — it never touches the engine itself.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::cost::kv::kv_cache_bytes;
use crate::cost::model_profile::{by_short_name, ModelProfile};
use crate::obs::MetricsRegistry;
use crate::plan::instance::{llm_units, DagTopology, LlmUnit};
use crate::plan::{ExecutionPlan, Role, Stage};
use crate::server::hostpool::{HostDone, HostPool, HostTask};
use crate::server::request::{ChatRequest, ChatResponse, StageSpan};
use crate::{Error, Result};

/// Globally-unique admission epochs: the host pool and the server's
/// completion channel outlive individual `serve` sessions, so epoch
/// uniqueness must span dispatchers — a stale completion or timer from
/// any earlier session must never match a later run reusing an id.
static EPOCH_SEQ: AtomicU64 = AtomicU64::new(1);

/// Fault-injection hook for host stages: `(op, request id) -> fail?`.
/// Installed via [`crate::server::Server::inject_host_fault`]; used by
/// the failure-injection tests to prove a failing tool node never
/// wedges the dispatcher.
pub type HostFault = Arc<dyn Fn(&str, u64) -> bool + Send + Sync>;

/// One virtual pipeline replica of the plan's fleet (live builds have a
/// single engine; the virtual fleet carries per-role routing, request
/// accounting, and chassis placement for edge-transfer modeling).
#[derive(Debug, Clone)]
pub struct VPipe {
    pub class: String,
    pub chassis: u32,
}

/// Static per-plan execution structure. See module docs.
pub struct DagRuntime {
    pub plan: ExecutionPlan,
    pub topo: DagTopology,
    pub units: Vec<LlmUnit>,
    pub unit_of: Vec<Option<usize>>,
    /// Incoming unit-external edge count per unit (readiness counter).
    unit_ext_edges: Vec<u32>,
    pub prefill_pipes: Vec<VPipe>,
    pub decode_pipes: Vec<VPipe>,
    model: Option<ModelProfile>,
    /// Uncontended scale-out bandwidth, bytes/second.
    xfer_bytes_per_s: f64,
    /// Wall-clock seconds per modeled second (CPU sleeps, transfers).
    pub time_scale: f64,
}

impl DagRuntime {
    pub fn new(plan: &ExecutionPlan, time_scale: f64) -> Result<DagRuntime> {
        plan.validate()?;
        if plan.bindings.is_empty() {
            return Err(Error::Runtime(
                "plan has no bindings to execute".into(),
            ));
        }
        let has_llm = plan.bindings.iter().any(|b| b.stage != Stage::Cpu);
        let model = by_short_name(&plan.model);
        if has_llm && model.is_none() {
            return Err(Error::Config(format!(
                "plan model `{}` not in the profile catalog",
                plan.model
            )));
        }
        let topo = DagTopology::of(plan);
        let (units, unit_of) = llm_units(plan);
        // `ext_deps` carries one entry per incoming external edge, so
        // its length is exactly the readiness count deliver_dep drains.
        let unit_ext_edges = units.iter().map(|u| u.ext_deps.len() as u32).collect();
        let placement = plan.placement()?;
        let vp = |specs: &[crate::cluster::sim::PipelineSpec]| -> Vec<VPipe> {
            specs
                .iter()
                .map(|s| VPipe {
                    class: s.device.name.to_string(),
                    chassis: s.chassis,
                })
                .collect()
        };
        Ok(DagRuntime {
            topo,
            units,
            unit_of,
            unit_ext_edges,
            prefill_pipes: vp(&placement.prefill),
            decode_pipes: vp(&placement.decode),
            model,
            xfer_bytes_per_s: (plan.fabric.scaleout_gbit * 1e9 / 8.0).max(1.0),
            time_scale: time_scale.max(0.0),
            plan: plan.clone(),
        })
    }

    /// Prompt tokens a node processes (byte-LM: bytes ≈ tokens), scaled
    /// by its `token_fraction` — mirrors `DagSim::isl_of`.
    fn isl_of(&self, prompt_len: usize, node: usize) -> u64 {
        let tf = self.plan.bindings[node].token_fraction;
        ((prompt_len as f64 * tf).round() as u64).max(1)
    }

    /// Decode token budget of a node — mirrors `DagSim::osl_of`.
    fn osl_of(&self, max_new: usize, node: usize) -> usize {
        let tf = self.plan.bindings[node].token_fraction;
        (((max_new as f64) * tf).round() as usize).max(1)
    }
}

/// One engine inference the serving loop should batch: unit `unit` of
/// request `req`.
#[derive(Debug, Clone)]
pub struct LlmJob {
    pub req: u64,
    pub unit: usize,
    pub prompt: Vec<u8>,
    /// Decode token budget (0 = prefill-only unit).
    pub osl: usize,
    pub temperature: f64,
}

/// What the engine did with one [`LlmJob`] (timestamps are wall-clock).
#[derive(Debug)]
pub struct UnitOutcome {
    pub job: LlmJob,
    /// Batch execution start (prefill stage start).
    pub started: Instant,
    pub prefill_end: Instant,
    pub first_token: Option<Instant>,
    /// Last decode token (== `prefill_end` when `osl == 0`).
    pub last_token: Instant,
    pub output: Vec<u8>,
    /// Sum and count of token-to-token gaps.
    pub tbt_sum_s: f64,
    pub tbt_n: u64,
}

/// What one dispatcher step produced: jobs for the batcher, responses
/// for the client channel.
#[derive(Debug, Default)]
pub struct Step {
    pub jobs: Vec<LlmJob>,
    pub responses: Vec<ChatResponse>,
}

/// A modeled cross-chassis transfer in flight: dependency `node` of
/// request `req` arrives at `due`. `epoch` pins the timer to one
/// admission of that id — a stale timer from a torn-down run must
/// never deliver into a later request reusing the id.
struct Timer {
    due: Instant,
    seq: u64,
    req: u64,
    node: usize,
    epoch: u64,
}

impl PartialEq for Timer {
    fn eq(&self, other: &Self) -> bool {
        self.due == other.due && self.seq == other.seq
    }
}
impl Eq for Timer {}
impl PartialOrd for Timer {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Timer {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.due.cmp(&other.due).then(self.seq.cmp(&other.seq))
    }
}

/// Per-request run state.
struct ReqRun {
    req: ChatRequest,
    /// Admission epoch (see [`Timer::epoch`]).
    epoch: u64,
    submitted: Instant,
    /// Unsatisfied dependency edges per node (CPU nodes).
    remaining: Vec<u32>,
    /// Unsatisfied external edges per unit (LLM nodes).
    unit_remaining: Vec<u32>,
    unit_dispatched: Vec<bool>,
    node_done: Vec<bool>,
    /// Virtual pipe each LLM node routed to.
    node_pipe: Vec<Option<(Role, usize)>>,
    pipe_released: Vec<bool>,
    nodes_left: usize,
    /// Host tasks + engine jobs currently in flight.
    outstanding: u32,
    failed: Option<String>,
    first_token: Option<Instant>,
    last_done: Instant,
    output: Vec<u8>,
    tokens: usize,
    tbt_sum_s: f64,
    tbt_n: u64,
    stages: Vec<Option<StageSpan>>,
}

/// The per-request dispatcher the serving loop drives. See module docs.
pub struct DagDispatch {
    runs: BTreeMap<u64, ReqRun>,
    timers: BinaryHeap<Reverse<Timer>>,
    timer_seq: u64,
    /// Outstanding LLM nodes routed to each virtual pipe, per role.
    prefill_load: Vec<usize>,
    decode_load: Vec<usize>,
    /// Per-binding stage-latency histograms, resolved once (the op set
    /// is fixed at plan install; no per-completion registry lookups).
    stage_hist: Vec<Arc<crate::obs::Histogram>>,
    metrics: Arc<MetricsRegistry>,
    fault: Option<HostFault>,
}

impl DagDispatch {
    pub fn new(
        rt: &DagRuntime,
        metrics: Arc<MetricsRegistry>,
        fault: Option<HostFault>,
    ) -> DagDispatch {
        let stage_hist = rt
            .plan
            .bindings
            .iter()
            .map(|b| metrics.stage_histogram(&b.op))
            .collect();
        DagDispatch {
            runs: BTreeMap::new(),
            timers: BinaryHeap::new(),
            timer_seq: 0,
            prefill_load: vec![0; rt.prefill_pipes.len()],
            decode_load: vec![0; rt.decode_pipes.len()],
            stage_hist,
            metrics,
            fault,
        }
    }

    /// Requests admitted but not yet answered.
    pub fn in_flight(&self) -> usize {
        self.runs.len()
    }

    /// Is a request with this id already in flight? (Duplicate ids
    /// would cross-apply completions; the server fails them closed.)
    pub fn contains(&self, id: u64) -> bool {
        self.runs.contains_key(&id)
    }

    /// Earliest pending modeled-transfer arrival, if any.
    pub fn next_timer_due(&self) -> Option<Instant> {
        self.timers.peek().map(|Reverse(t)| t.due)
    }

    /// Admit one agent request: instantiate its DAG, dispatch the
    /// roots. Host stages go straight to the pool; ready LLM units come
    /// back in the [`Step`] for the batcher.
    pub fn admit(
        &mut self,
        rt: &DagRuntime,
        req: ChatRequest,
        now: Instant,
        pool: &HostPool,
    ) -> Step {
        let mut step = Step::default();
        let n = rt.topo.len();
        let mut run = ReqRun {
            epoch: EPOCH_SEQ.fetch_add(1, Ordering::Relaxed),
            submitted: now,
            remaining: rt.topo.indeg.clone(),
            unit_remaining: rt.unit_ext_edges.clone(),
            unit_dispatched: vec![false; rt.units.len()],
            node_done: vec![false; n],
            node_pipe: vec![None; n],
            pipe_released: vec![false; n],
            nodes_left: n,
            outstanding: 0,
            failed: None,
            first_token: None,
            last_done: now,
            output: Vec::new(),
            tokens: 0,
            tbt_sum_s: 0.0,
            tbt_n: 0,
            stages: vec![None; n],
            req,
        };
        // CPU roots.
        for node in rt.topo.roots() {
            if rt.plan.bindings[node].stage == Stage::Cpu {
                self.dispatch_cpu(rt, &mut run, node, pool);
            }
        }
        // Units with no external edges are ready at arrival.
        for u in 0..rt.units.len() {
            if run.unit_remaining[u] == 0 && !run.unit_dispatched[u] {
                self.dispatch_unit(rt, &mut run, u, &mut step);
            }
        }
        self.runs.insert(run.req.id, run);
        step
    }

    /// One host-pool completion landed.
    pub fn on_host_done(&mut self, rt: &DagRuntime, d: HostDone, pool: &HostPool) -> Step {
        let mut step = Step::default();
        let Some(mut run) = self.runs.remove(&d.req) else {
            return step;
        };
        // A stale completion from an earlier serve session (or an
        // earlier admission of this id) belongs to a torn-down run.
        if run.epoch != d.epoch {
            self.runs.insert(d.req, run);
            return step;
        }
        run.outstanding = run.outstanding.saturating_sub(1);
        match d.result {
            Ok(()) => {
                if run.failed.is_none() {
                    let span = StageSpan {
                        node: d.node,
                        op: rt.plan.bindings[d.node].op.clone(),
                        role: rt.plan.bindings[d.node].stage.name(),
                        start_s: d.started.duration_since(run.submitted).as_secs_f64(),
                        end_s: d.finished.duration_since(run.submitted).as_secs_f64(),
                    };
                    self.complete_node(rt, &mut run, d.node, d.finished, span, pool, &mut step);
                }
            }
            Err(e) => {
                if run.failed.is_none() {
                    self.metrics.counter("server_stage_failures").inc();
                    run.failed = Some(format!(
                        "{} (node {}): {e}",
                        rt.plan.bindings[d.node].op, d.node
                    ));
                }
                // The failing stage's own wall time still counts
                // toward the failed response's e2e.
                if d.finished > run.last_done {
                    run.last_done = d.finished;
                }
            }
        }
        self.settle(run, &mut step);
        step
    }

    /// Deliver every modeled transfer due by `now`.
    pub fn poll_timers(&mut self, rt: &DagRuntime, now: Instant, pool: &HostPool) -> Step {
        let mut step = Step::default();
        while matches!(self.timers.peek(), Some(Reverse(t)) if t.due <= now) {
            let Reverse(t) = self.timers.pop().unwrap();
            let Some(mut run) = self.runs.remove(&t.req) else {
                continue;
            };
            // A stale timer from a torn-down run must not deliver into
            // a later request that reused the id.
            if run.epoch != t.epoch {
                self.runs.insert(t.req, run);
                continue;
            }
            if run.failed.is_none() {
                self.deliver_dep(rt, &mut run, t.node, pool, &mut step);
            }
            self.settle(run, &mut step);
        }
        step
    }

    /// The engine finished a batch of units.
    pub fn finish_units(
        &mut self,
        rt: &DagRuntime,
        outcomes: Vec<UnitOutcome>,
        pool: &HostPool,
    ) -> Step {
        let mut step = Step::default();
        for o in outcomes {
            let Some(mut run) = self.runs.remove(&o.job.req) else {
                continue;
            };
            run.outstanding = run.outstanding.saturating_sub(1);
            if run.failed.is_none() {
                let unit = &rt.units[o.job.unit];
                run.output.extend_from_slice(&o.output);
                run.tokens += o.output.len();
                if let Some(ft) = o.first_token {
                    let earlier = match run.first_token {
                        Some(cur) => ft < cur,
                        None => true,
                    };
                    if earlier {
                        run.first_token = Some(ft);
                    }
                }
                run.tbt_sum_s += o.tbt_sum_s;
                run.tbt_n += o.tbt_n;
                if let Some(p) = unit.prefill {
                    let span = StageSpan {
                        node: p,
                        op: rt.plan.bindings[p].op.clone(),
                        role: rt.plan.bindings[p].stage.name(),
                        start_s: o.started.duration_since(run.submitted).as_secs_f64(),
                        end_s: o.prefill_end.duration_since(run.submitted).as_secs_f64(),
                    };
                    self.complete_node(rt, &mut run, p, o.prefill_end, span, pool, &mut step);
                }
                if let Some(dnode) = unit.decode {
                    if run.failed.is_none() {
                        let span = StageSpan {
                            node: dnode,
                            op: rt.plan.bindings[dnode].op.clone(),
                            role: rt.plan.bindings[dnode].stage.name(),
                            start_s: o
                                .prefill_end
                                .duration_since(run.submitted)
                                .as_secs_f64(),
                            end_s: o.last_token.duration_since(run.submitted).as_secs_f64(),
                        };
                        self.complete_node(
                            rt, &mut run, dnode, o.last_token, span, pool, &mut step,
                        );
                    }
                }
            }
            self.settle(run, &mut step);
        }
        step
    }

    /// Re-insert the run or finalize it into a response.
    fn settle(&mut self, run: ReqRun, step: &mut Step) {
        if let Some(err) = &run.failed {
            if run.outstanding == 0 {
                let e2e = run.last_done.duration_since(run.submitted).as_secs_f64();
                self.release_pipes(&run);
                step.responses
                    .push(ChatResponse::failed(run.req.id, e2e, err.clone()));
                return;
            }
        } else if run.nodes_left == 0 {
            self.release_pipes(&run);
            step.responses.push(finalize(run));
            return;
        }
        self.runs.insert(run.req.id, run);
    }

    /// Return any still-held virtual-pipe slots (failure teardown).
    fn release_pipes(&mut self, run: &ReqRun) {
        for (node, p) in run.node_pipe.iter().enumerate() {
            if let Some((role, k)) = p {
                if !run.pipe_released[node] {
                    match role {
                        Role::Prefill => {
                            self.prefill_load[*k] = self.prefill_load[*k].saturating_sub(1)
                        }
                        Role::Decode => {
                            self.decode_load[*k] = self.decode_load[*k].saturating_sub(1)
                        }
                    }
                }
            }
        }
    }

    /// Route an LLM node to the least-loaded virtual pipe of its class.
    fn assign_pipe(&mut self, rt: &DagRuntime, run: &mut ReqRun, node: usize) {
        if run.node_pipe[node].is_some() {
            return;
        }
        let binding = &rt.plan.bindings[node];
        let (pipes, loads, role) = match binding.stage {
            Stage::LlmPrefill => (&rt.prefill_pipes, &mut self.prefill_load, Role::Prefill),
            Stage::LlmDecode => (&rt.decode_pipes, &mut self.decode_load, Role::Decode),
            Stage::Cpu => return,
        };
        let k = (0..pipes.len())
            .filter(|&k| pipes[k].class == binding.class)
            .min_by_key(|&k| loads[k]);
        if let Some(k) = k {
            loads[k] += 1;
            run.node_pipe[node] = Some((role, k));
        }
    }

    fn chassis_of(rt: &DagRuntime, run: &ReqRun, node: usize) -> Option<u32> {
        match run.node_pipe[node] {
            Some((Role::Prefill, k)) => Some(rt.prefill_pipes[k].chassis),
            Some((Role::Decode, k)) => Some(rt.decode_pipes[k].chassis),
            None => None,
        }
    }

    /// Submit one CPU/tool/IO stage to the host pool.
    fn dispatch_cpu(&mut self, rt: &DagRuntime, run: &mut ReqRun, node: usize, pool: &HostPool) {
        let binding = &rt.plan.bindings[node];
        let sleep_s = binding.latency_s * rt.time_scale;
        let op = binding.op.clone();
        let req_id = run.req.id;
        let fault = self.fault.clone();
        run.outstanding += 1;
        self.metrics.counter("server_host_jobs").inc();
        pool.submit(HostTask {
            req: req_id,
            node,
            epoch: run.epoch,
            work: Box::new(move || {
                if sleep_s > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(sleep_s));
                }
                if let Some(f) = fault {
                    if f(&op, req_id) {
                        return Err(Error::Runtime(format!(
                            "injected host-stage fault in {op}"
                        )));
                    }
                }
                Ok(())
            }),
        });
    }

    /// Emit one ready LLM unit as a job for the batcher.
    fn dispatch_unit(&mut self, rt: &DagRuntime, run: &mut ReqRun, unit: usize, step: &mut Step) {
        run.unit_dispatched[unit] = true;
        run.outstanding += 1;
        let u = &rt.units[unit];
        for m in u.members() {
            self.assign_pipe(rt, run, m);
        }
        if u.prefill.is_some() {
            self.metrics.counter("server_prefill_jobs").inc();
        }
        let osl = match u.decode {
            Some(d) => {
                self.metrics.counter("server_decode_jobs").inc();
                rt.osl_of(run.req.max_new_tokens, d)
            }
            None => 0,
        };
        step.jobs.push(LlmJob {
            req: run.req.id,
            unit,
            prompt: run.req.prompt.clone(),
            osl,
            temperature: run.req.temperature,
        });
    }

    /// One dependency edge into `node` is satisfied.
    fn deliver_dep(
        &mut self,
        rt: &DagRuntime,
        run: &mut ReqRun,
        node: usize,
        pool: &HostPool,
        step: &mut Step,
    ) {
        match rt.plan.bindings[node].stage {
            Stage::Cpu => {
                run.remaining[node] = run.remaining[node].saturating_sub(1);
                if run.remaining[node] == 0 {
                    self.dispatch_cpu(rt, run, node, pool);
                }
            }
            Stage::LlmPrefill | Stage::LlmDecode => {
                let u = rt.unit_of[node].expect("LLM node must belong to a unit");
                run.unit_remaining[u] = run.unit_remaining[u].saturating_sub(1);
                if run.unit_remaining[u] == 0 && !run.unit_dispatched[u] {
                    self.dispatch_unit(rt, run, u, step);
                }
            }
        }
    }

    /// Node finished: record its span, release its pipe slot, and
    /// propagate to successors (with modeled cross-chassis transfer
    /// delays on pipeline → pipeline edges, as in the simulator).
    #[allow(clippy::too_many_arguments)]
    fn complete_node(
        &mut self,
        rt: &DagRuntime,
        run: &mut ReqRun,
        node: usize,
        end: Instant,
        span: StageSpan,
        pool: &HostPool,
        step: &mut Step,
    ) {
        if run.node_done[node] {
            return;
        }
        run.node_done[node] = true;
        self.stage_hist[node].record_secs(span.duration_s());
        run.stages[node] = Some(span);
        if end > run.last_done {
            run.last_done = end;
        }
        run.nodes_left -= 1;
        if let Some((role, k)) = run.node_pipe[node] {
            if !run.pipe_released[node] {
                run.pipe_released[node] = true;
                match role {
                    Role::Prefill => {
                        self.prefill_load[k] = self.prefill_load[k].saturating_sub(1)
                    }
                    Role::Decode => {
                        self.decode_load[k] = self.decode_load[k].saturating_sub(1)
                    }
                }
            }
        }
        let from_chassis = Self::chassis_of(rt, run, node);
        let from_stage = rt.plan.bindings[node].stage;
        for &v in &rt.topo.succ[node] {
            if run.failed.is_some() {
                break;
            }
            // Intra-unit edges (prefill → its fused decode) execute
            // back-to-back inside one engine pass; KV never leaves the
            // device, so there is nothing to deliver or transfer.
            if rt.unit_of[node].is_some() && rt.unit_of[node] == rt.unit_of[v] {
                continue;
            }
            let to_binding = &rt.plan.bindings[v];
            let mut delay_s = 0.0;
            // Pipeline → pipeline edges pay the modeled fabric hop;
            // host stages ingest as part of their profiled latency.
            if to_binding.stage != Stage::Cpu && from_chassis.is_some() {
                self.assign_pipe(rt, run, v);
                if let Some(to_chassis) = Self::chassis_of(rt, run, v) {
                    if from_chassis != Some(to_chassis) {
                        let bytes = if from_stage == Stage::LlmPrefill
                            && to_binding.stage == Stage::LlmDecode
                        {
                            match &rt.model {
                                Some(m) => kv_cache_bytes(
                                    m,
                                    rt.isl_of(run.req.prompt.len(), v),
                                    1,
                                ),
                                None => to_binding.xfer_bytes,
                            }
                        } else {
                            to_binding.xfer_bytes
                        };
                        delay_s = bytes / rt.xfer_bytes_per_s * rt.time_scale;
                    }
                }
            }
            if delay_s > 1e-6 {
                self.timer_seq += 1;
                self.timers.push(Reverse(Timer {
                    due: end + Duration::from_secs_f64(delay_s),
                    seq: self.timer_seq,
                    req: run.req.id,
                    node: v,
                    epoch: run.epoch,
                }));
            } else {
                self.deliver_dep(rt, run, v, pool, step);
            }
        }
    }
}

/// Build the final response for a fully-executed request.
fn finalize(run: ReqRun) -> ChatResponse {
    let e2e = run.last_done.duration_since(run.submitted).as_secs_f64();
    let ttft = match run.first_token {
        Some(ft) => ft.duration_since(run.submitted).as_secs_f64(),
        // No decode stages: time to completion (the simulator's rule).
        None => e2e,
    };
    let tbt = if run.tbt_n > 0 {
        run.tbt_sum_s / run.tbt_n as f64
    } else {
        0.0
    };
    let mut stages: Vec<StageSpan> = run.stages.into_iter().flatten().collect();
    stages.sort_by(|a, b| {
        a.start_s
            .partial_cmp(&b.start_s)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    ChatResponse {
        id: run.req.id,
        output: run.output,
        ttft_s: ttft,
        tbt_mean_s: tbt,
        e2e_s: e2e,
        tokens: run.tokens,
        rejected: false,
        failed: false,
        error: None,
        stages,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::tests::tiny_plan;

    #[test]
    fn runtime_derives_units_and_pipes() {
        let plan = tiny_plan();
        let rt = DagRuntime::new(&plan, 1.0).unwrap();
        assert_eq!(rt.topo.len(), 4);
        assert_eq!(rt.units.len(), 1);
        assert_eq!(rt.unit_ext_edges, vec![1]); // cpu input → prefill
        assert_eq!(rt.prefill_pipes.len(), 1);
        assert_eq!(rt.decode_pipes.len(), 2); // 2 replicas expanded
        assert_eq!(rt.decode_pipes[0].chassis, 1);
        assert_eq!(rt.decode_pipes[1].chassis, 2);
    }

    #[test]
    fn runtime_rejects_unknown_model() {
        let mut plan = tiny_plan();
        plan.model = "unknown-model".into();
        assert!(DagRuntime::new(&plan, 1.0).is_err());
    }

    #[test]
    fn osl_scales_with_token_fraction() {
        let mut plan = tiny_plan();
        plan.bindings[2].token_fraction = 0.5;
        let rt = DagRuntime::new(&plan, 1.0).unwrap();
        assert_eq!(rt.osl_of(24, 2), 12);
        assert_eq!(rt.osl_of(1, 2), 1, "floors at one token");
        assert_eq!(rt.isl_of(100, 2), 50);
    }
}
