//! Bounded host-side worker pool: executes the CPU/tool/IO stages of
//! live agent-DAG requests (the counterpart of the simulator's
//! `cpu_workers` slot pool in `cluster/dag.rs`).
//!
//! Design: `capacity` OS threads pull [`HostTask`]s from one shared
//! queue (`Mutex<Receiver>` — the lock is held only across the blocking
//! `recv`, so exactly one idle worker waits at a time and hand-off is
//! FIFO). Completions flow back to the dispatcher through a pluggable
//! sink — an mpsc sender by default ([`HostPool::new`]), or any closure
//! ([`HostPool::with_sink`]) so the threaded server can merge host
//! completions into its unified event channel; the pool never blocks
//! the serving loop. Task closures that
//! panic are caught and surfaced as `Err`, so a hostile tool stage can
//! fail its request but never leak a worker or wedge the dispatcher.
//!
//! The pool is resizable in place ([`HostPool::resize`]) — the server
//! re-derives its size from each new `ExecutionPlan`'s `cpu_workers`
//! on reconfiguration.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;
use std::time::Instant;

use crate::Result;

/// One unit of host work: node `node` of request `req`.
pub struct HostTask {
    pub req: u64,
    pub node: usize,
    /// Admission epoch of the owning run (see
    /// [`crate::server::dag_exec`]): completions are ignored unless
    /// the epoch still matches, so a stale completion from an earlier
    /// serve session can never cross-apply to a request reusing an id.
    pub epoch: u64,
    /// When the dispatcher queued the task — `started - submitted` is
    /// the pool queue wait (`Span::queue_wait` for host stages).
    pub submitted: Instant,
    /// The actual stage body (tool call, IO, pre/post-processing).
    /// Returns the stage's output **payload** — real bytes the
    /// dispatcher hands to downstream stages (tool results feed the
    /// next LLM prompt), not just a latency model.
    pub work: Box<dyn FnOnce() -> Result<Vec<u8>> + Send + 'static>,
}

/// Completion record delivered back to the dispatcher.
#[derive(Debug)]
pub struct HostDone {
    pub req: u64,
    pub node: usize,
    pub epoch: u64,
    /// Stage payload on success (propagated along DAG edges).
    pub result: Result<Vec<u8>>,
    /// Echoed from [`HostTask::submitted`] (queue-wait attribution).
    pub submitted: Instant,
    pub started: Instant,
    pub finished: Instant,
}

enum Msg {
    Task(HostTask),
    Stop,
}

/// Where completions go. The threaded server injects a closure that
/// wraps each [`HostDone`] into its unified dispatcher event; plain
/// channel consumers get the [`HostPool::new`] adapter.
type DoneSink = Arc<dyn Fn(HostDone) + Send + Sync>;

/// Shared pool counters (atomics — read from the dispatcher thread).
#[derive(Debug, Default)]
struct PoolStats {
    /// Nanoseconds of task execution across all workers.
    busy_ns: AtomicU64,
    /// Tasks currently executing.
    running: AtomicU64,
    /// Max of `running` ever observed (capacity-bound witness).
    high_watermark: AtomicU64,
    /// Tasks finished (ok or err).
    completed: AtomicU64,
    /// Tasks submitted but not yet started.
    queued: AtomicU64,
    /// Workers currently alive vs the configured capacity. Workers
    /// self-retire (CAS on `alive`) whenever `alive > target`, checked
    /// after every task — so a shrink takes effect as soon as each
    /// surplus worker finishes its current task, even under backlog.
    alive: AtomicU64,
    target: AtomicU64,
}

/// Retire this worker if the pool is over its target width.
fn try_retire(stats: &PoolStats) -> bool {
    let target = stats.target.load(Ordering::SeqCst);
    loop {
        let alive = stats.alive.load(Ordering::SeqCst);
        if alive <= target {
            return false;
        }
        if stats
            .alive
            .compare_exchange(alive, alive - 1, Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
        {
            return true;
        }
    }
}

/// The bounded worker pool. See module docs.
pub struct HostPool {
    tx: mpsc::Sender<Msg>,
    rx: Arc<Mutex<mpsc::Receiver<Msg>>>,
    done: DoneSink,
    handles: Vec<thread::JoinHandle<()>>,
    capacity: usize,
    stats: Arc<PoolStats>,
    /// busy_ns already handed out by `take_busy_seconds`.
    busy_taken_ns: u64,
}

impl HostPool {
    /// Spawn `capacity` workers (≥ 1). Completions go out on `done_tx`.
    pub fn new(capacity: usize, done_tx: mpsc::Sender<HostDone>) -> HostPool {
        Self::with_sink(capacity, move |d| {
            // Dispatcher gone ⇒ nothing left to notify.
            let _ = done_tx.send(d);
        })
    }

    /// Spawn `capacity` workers delivering completions to an arbitrary
    /// sink. The sink runs on worker threads, so it must be cheap and
    /// non-blocking (a channel send).
    pub fn with_sink(
        capacity: usize,
        sink: impl Fn(HostDone) + Send + Sync + 'static,
    ) -> HostPool {
        let capacity = capacity.max(1);
        let (tx, rx) = mpsc::channel();
        let rx = Arc::new(Mutex::new(rx));
        let stats = Arc::new(PoolStats::default());
        stats.target.store(capacity as u64, Ordering::SeqCst);
        let mut pool = HostPool {
            tx,
            rx,
            done: Arc::new(sink),
            handles: Vec::new(),
            capacity: 0,
            stats,
            busy_taken_ns: 0,
        };
        pool.spawn_workers(capacity);
        pool.capacity = capacity;
        pool
    }

    fn spawn_workers(&mut self, n: usize) {
        for _ in 0..n {
            let rx = Arc::clone(&self.rx);
            let done = Arc::clone(&self.done);
            let stats = Arc::clone(&self.stats);
            stats.alive.fetch_add(1, Ordering::SeqCst);
            self.handles.push(thread::spawn(move || loop {
                // Hold the lock only for the blocking recv: one idle
                // worker waits; the rest park on the mutex.
                let msg = match rx.lock() {
                    Ok(guard) => guard.recv(),
                    Err(_) => break, // poisoned: pool is going away
                };
                match msg {
                    Ok(Msg::Task(t)) => {
                        stats.queued.fetch_sub(1, Ordering::SeqCst);
                        let running = stats.running.fetch_add(1, Ordering::SeqCst) + 1;
                        stats.high_watermark.fetch_max(running, Ordering::SeqCst);
                        let started = Instant::now();
                        let result =
                            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                                t.work,
                            )) {
                                Ok(r) => r,
                                Err(_) => Err(crate::Error::Runtime(format!(
                                    "host stage panicked (req {}, node {})",
                                    t.req, t.node
                                ))),
                            };
                        let finished = Instant::now();
                        stats.busy_ns.fetch_add(
                            finished.duration_since(started).as_nanos() as u64,
                            Ordering::SeqCst,
                        );
                        stats.running.fetch_sub(1, Ordering::SeqCst);
                        stats.completed.fetch_add(1, Ordering::SeqCst);
                        done(HostDone {
                            req: t.req,
                            node: t.node,
                            epoch: t.epoch,
                            result,
                            submitted: t.submitted,
                            started,
                            finished,
                        });
                        // Shrinks land here: a surplus worker exits as
                        // soon as its current task is done, even when
                        // the queue is deep.
                        if try_retire(&stats) {
                            break;
                        }
                    }
                    // Stop is a wakeup for blocked workers; it only
                    // retires this worker if the pool is still over
                    // target (a busy worker may have retired already).
                    Ok(Msg::Stop) => {
                        if try_retire(&stats) {
                            break;
                        }
                    }
                    Err(_) => break,
                }
            }));
        }
    }

    /// Queue one task (FIFO; starts as soon as a worker frees up).
    pub fn submit(&self, task: HostTask) {
        self.stats.queued.fetch_add(1, Ordering::SeqCst);
        // Send can only fail if every worker exited, which only happens
        // on shutdown — the pool outlives all submitters by design.
        let _ = self.tx.send(Msg::Task(task));
    }

    /// Grow or shrink the worker set. Shrinking is graceful but
    /// prompt: surplus workers exit as soon as their *current* task
    /// finishes (idle workers are woken to retire immediately) — they
    /// do not keep draining a deep backlog at the old width.
    pub fn resize(&mut self, new_capacity: usize) {
        let new_capacity = new_capacity.max(1);
        // Reap handles of workers that already self-retired so the
        // vec tracks ~live workers across many resize cycles.
        self.handles.retain(|h| !h.is_finished());
        self.stats
            .target
            .store(new_capacity as u64, Ordering::SeqCst);
        // Grow/shrink against the *live* worker count, not the old
        // configured capacity: pending retirees from an earlier shrink
        // count toward the new target (their try_retire now no-ops),
        // so a shrink→grow sequence never overshoots the bound.
        let alive = self.stats.alive.load(Ordering::SeqCst) as usize;
        if new_capacity > alive {
            self.spawn_workers(new_capacity - alive);
        } else {
            for _ in new_capacity..alive {
                let _ = self.tx.send(Msg::Stop);
            }
        }
        self.capacity = new_capacity;
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Tasks submitted but not yet started.
    pub fn queued(&self) -> u64 {
        self.stats.queued.load(Ordering::SeqCst)
    }

    pub fn completed(&self) -> u64 {
        self.stats.completed.load(Ordering::SeqCst)
    }

    /// Max concurrently-running tasks ever observed.
    pub fn high_watermark(&self) -> u64 {
        self.stats.high_watermark.load(Ordering::SeqCst)
    }

    /// Total worker-busy seconds since construction.
    pub fn busy_seconds(&self) -> f64 {
        self.stats.busy_ns.load(Ordering::SeqCst) as f64 / 1e9
    }

    /// Busy seconds accumulated since the last call (windowed
    /// utilization for the orchestrator's live backend).
    pub fn take_busy_seconds(&mut self) -> f64 {
        let total = self.stats.busy_ns.load(Ordering::SeqCst);
        let delta = total.saturating_sub(self.busy_taken_ns);
        self.busy_taken_ns = total;
        delta as f64 / 1e9
    }
}

impl Drop for HostPool {
    fn drop(&mut self) {
        // Target 0 retires every worker (busy ones after their current
        // task); the Stops wake anyone blocked on the empty queue.
        self.stats.target.store(0, Ordering::SeqCst);
        for _ in 0..self.handles.len() {
            let _ = self.tx.send(Msg::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn tasks_complete_and_report() {
        let (done_tx, done_rx) = mpsc::channel();
        let pool = HostPool::new(2, done_tx);
        for i in 0..6u64 {
            pool.submit(HostTask {
                req: i,
                node: 0,
                epoch: 0,
                submitted: Instant::now(),
                work: Box::new(|| {
                    thread::sleep(Duration::from_millis(1));
                    Ok(b"payload".to_vec())
                }),
            });
        }
        let mut seen = Vec::new();
        for _ in 0..6 {
            let d = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
            assert!(d.result.is_ok());
            assert!(d.finished >= d.started);
            seen.push(d.req);
        }
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3, 4, 5]);
        assert!(pool.high_watermark() <= 2);
        assert_eq!(pool.completed(), 6);
        assert!(pool.busy_seconds() > 0.0);
    }

    #[test]
    fn panicking_task_fails_closed_and_pool_survives() {
        let (done_tx, done_rx) = mpsc::channel();
        let pool = HostPool::new(1, done_tx);
        pool.submit(HostTask {
            req: 1,
            node: 0,
            epoch: 0,
                submitted: Instant::now(),
            work: Box::new(|| panic!("hostile tool")),
        });
        pool.submit(HostTask {
            req: 2,
            node: 0,
            epoch: 0,
                submitted: Instant::now(),
            work: Box::new(|| Ok(Vec::new())),
        });
        let d1 = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(d1.result.is_err(), "panic must surface as Err");
        let d2 = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(d2.result.is_ok(), "pool must survive a panicking task");
        assert_eq!(d2.req, 2);
    }

    #[test]
    fn resize_grows_and_shrinks() {
        let (done_tx, done_rx) = mpsc::channel();
        let mut pool = HostPool::new(1, done_tx);
        assert_eq!(pool.capacity(), 1);
        pool.resize(4);
        assert_eq!(pool.capacity(), 4);
        // 4 concurrent sleepers: with 4 workers they overlap.
        for i in 0..4u64 {
            pool.submit(HostTask {
                req: i,
                node: 0,
                epoch: 0,
                submitted: Instant::now(),
                work: Box::new(|| {
                    thread::sleep(Duration::from_millis(20));
                    Ok(Vec::new())
                }),
            });
        }
        let t0 = Instant::now();
        for _ in 0..4 {
            done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        }
        assert!(
            t0.elapsed() < Duration::from_millis(70),
            "grown pool must run sleepers in parallel"
        );
        pool.resize(1);
        assert_eq!(pool.capacity(), 1);
        // Still serves work after the shrink.
        pool.submit(HostTask {
            req: 9,
            node: 0,
            epoch: 0,
                submitted: Instant::now(),
            work: Box::new(|| Ok(Vec::new())),
        });
        let d = done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(d.req, 9);
    }

    #[test]
    fn sink_constructor_delivers_completions() {
        use std::sync::atomic::AtomicU64;
        let seen = Arc::new(AtomicU64::new(0));
        let seen2 = Arc::clone(&seen);
        let pool = HostPool::with_sink(2, move |d: HostDone| {
            assert!(d.result.is_ok());
            seen2.fetch_add(1, Ordering::SeqCst);
        });
        for i in 0..4u64 {
            pool.submit(HostTask {
                req: i,
                node: 0,
                epoch: 0,
                submitted: Instant::now(),
                work: Box::new(|| Ok(Vec::new())),
            });
        }
        let t0 = Instant::now();
        while seen.load(Ordering::SeqCst) < 4 {
            assert!(t0.elapsed() < Duration::from_secs(5), "sink never saw 4 completions");
            thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(pool.completed(), 4);
    }

    #[test]
    fn take_busy_seconds_is_windowed() {
        let (done_tx, done_rx) = mpsc::channel();
        let mut pool = HostPool::new(1, done_tx);
        pool.submit(HostTask {
            req: 0,
            node: 0,
            epoch: 0,
                submitted: Instant::now(),
            work: Box::new(|| {
                thread::sleep(Duration::from_millis(5));
                Ok(Vec::new())
            }),
        });
        done_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        let first = pool.take_busy_seconds();
        assert!(first > 0.0);
        assert_eq!(pool.take_busy_seconds(), 0.0, "window must reset");
    }
}
