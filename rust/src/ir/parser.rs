//! Textual IR parser — inverse of [`super::printer`].
//!
//! Grammar (whitespace-insensitive, `//` comments):
//!
//! ```text
//! module   := "graph" "@" ident "(" valuelist? ")" block
//! block    := "{" stmt* yield? "}"
//! stmt     := (valuelist "=")? opname "(" valuelist? ")" attrs? block?
//! yield    := "yield" valuelist
//! attrs    := "{" (ident "=" attrval ("," ident "=" attrval)*)? "}"
//! attrval  := int | float | string | bool | "[" attrval,* "]"
//! value    := "%" int
//! ```

use std::collections::BTreeMap;

use super::attr::Attr;
use super::graph::{Graph, Node, NodeId, ValueId};
use crate::{Error, Result};

#[derive(Debug, Clone, PartialEq)]
enum Tok {
    Value(u32),
    At,
    Ident(String),
    Str(String),
    Int(i64),
    Float(f64),
    LParen,
    RParen,
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    Comma,
    Eq,
}

struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
    line: usize,
}

impl<'a> Lexer<'a> {
    fn new(src: &'a str) -> Self {
        Lexer {
            src: src.as_bytes(),
            pos: 0,
            line: 1,
        }
    }

    fn err(&self, msg: impl Into<String>) -> Error {
        Error::Parse {
            line: self.line,
            msg: msg.into(),
        }
    }

    fn tokens(mut self) -> Result<Vec<(Tok, usize)>> {
        let mut out = Vec::new();
        while self.pos < self.src.len() {
            let c = self.src[self.pos] as char;
            match c {
                ' ' | '\t' | '\r' => self.pos += 1,
                '\n' => {
                    self.line += 1;
                    self.pos += 1;
                }
                '/' if self.peek(1) == Some('/') => {
                    while self.pos < self.src.len() && self.src[self.pos] != b'\n' {
                        self.pos += 1;
                    }
                }
                '%' => {
                    self.pos += 1;
                    let n = self.lex_uint()?;
                    out.push((Tok::Value(n as u32), self.line));
                }
                '@' => {
                    self.pos += 1;
                    out.push((Tok::At, self.line));
                }
                '(' => {
                    self.pos += 1;
                    out.push((Tok::LParen, self.line));
                }
                ')' => {
                    self.pos += 1;
                    out.push((Tok::RParen, self.line));
                }
                '{' => {
                    self.pos += 1;
                    out.push((Tok::LBrace, self.line));
                }
                '}' => {
                    self.pos += 1;
                    out.push((Tok::RBrace, self.line));
                }
                '[' => {
                    self.pos += 1;
                    out.push((Tok::LBracket, self.line));
                }
                ']' => {
                    self.pos += 1;
                    out.push((Tok::RBracket, self.line));
                }
                ',' => {
                    self.pos += 1;
                    out.push((Tok::Comma, self.line));
                }
                '=' => {
                    self.pos += 1;
                    out.push((Tok::Eq, self.line));
                }
                '"' => {
                    let s = self.lex_string()?;
                    out.push((Tok::Str(s), self.line));
                }
                c if c.is_ascii_digit() || c == '-' => {
                    let (tok, _) = self.lex_number()?;
                    out.push((tok, self.line));
                }
                c if c.is_ascii_alphabetic() || c == '_' => {
                    let id = self.lex_ident();
                    out.push((Tok::Ident(id), self.line));
                }
                other => return Err(self.err(format!("unexpected character {other:?}"))),
            }
        }
        Ok(out)
    }

    fn peek(&self, k: usize) -> Option<char> {
        self.src.get(self.pos + k).map(|b| *b as char)
    }

    fn lex_uint(&mut self) -> Result<u64> {
        let start = self.pos;
        while self.pos < self.src.len() && self.src[self.pos].is_ascii_digit() {
            self.pos += 1;
        }
        if start == self.pos {
            return Err(self.err("expected digits"));
        }
        std::str::from_utf8(&self.src[start..self.pos])
            .unwrap()
            .parse()
            .map_err(|e| self.err(format!("bad integer: {e}")))
    }

    fn lex_number(&mut self) -> Result<(Tok, ())> {
        let start = self.pos;
        if self.src[self.pos] == b'-' {
            self.pos += 1;
        }
        let mut is_float = false;
        while self.pos < self.src.len() {
            let b = self.src[self.pos];
            if b.is_ascii_digit() {
                self.pos += 1;
            } else if b == b'.' || b == b'e' || b == b'E'
                || ((b == b'+' || b == b'-')
                    && matches!(self.src.get(self.pos - 1), Some(b'e') | Some(b'E')))
            {
                is_float = true;
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        if is_float {
            Ok((
                Tok::Float(
                    text.parse()
                        .map_err(|e| self.err(format!("bad float {text:?}: {e}")))?,
                ),
                (),
            ))
        } else {
            Ok((
                Tok::Int(
                    text.parse()
                        .map_err(|e| self.err(format!("bad int {text:?}: {e}")))?,
                ),
                (),
            ))
        }
    }

    fn lex_string(&mut self) -> Result<String> {
        self.pos += 1; // opening quote
        let mut s = String::new();
        while self.pos < self.src.len() {
            match self.src[self.pos] {
                b'"' => {
                    self.pos += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.pos += 1;
                    match self.src.get(self.pos) {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'n') => s.push('\n'),
                        other => {
                            return Err(self.err(format!("bad escape {other:?}")))
                        }
                    }
                    self.pos += 1;
                }
                b => {
                    if b == b'\n' {
                        self.line += 1;
                    }
                    s.push(b as char);
                    self.pos += 1;
                }
            }
        }
        Err(self.err("unterminated string"))
    }

    fn lex_ident(&mut self) -> String {
        let start = self.pos;
        while self.pos < self.src.len() {
            let b = self.src[self.pos];
            if b.is_ascii_alphanumeric() || b == b'_' || b == b'.' {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.src[start..self.pos])
            .unwrap()
            .to_string()
    }
}

struct Parser {
    toks: Vec<(Tok, usize)>,
    pos: usize,
}

impl Parser {
    fn err(&self, msg: impl Into<String>) -> Error {
        let line = self
            .toks
            .get(self.pos.min(self.toks.len().saturating_sub(1)))
            .map(|(_, l)| *l)
            .unwrap_or(0);
        Error::Parse {
            line,
            msg: msg.into(),
        }
    }

    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos).map(|(t, _)| t)
    }

    fn peek2(&self) -> Option<&Tok> {
        self.toks.get(self.pos + 1).map(|(t, _)| t)
    }

    fn next(&mut self) -> Option<Tok> {
        let t = self.toks.get(self.pos).map(|(t, _)| t.clone());
        self.pos += 1;
        t
    }

    fn expect(&mut self, t: Tok) -> Result<()> {
        match self.next() {
            Some(got) if got == t => Ok(()),
            got => Err(self.err(format!("expected {t:?}, got {got:?}"))),
        }
    }

    fn expect_ident(&mut self, kw: &str) -> Result<()> {
        match self.next() {
            Some(Tok::Ident(s)) if s == kw => Ok(()),
            got => Err(self.err(format!("expected `{kw}`, got {got:?}"))),
        }
    }

    fn value_list(&mut self) -> Result<Vec<ValueId>> {
        let mut out = Vec::new();
        loop {
            match self.peek() {
                Some(Tok::Value(n)) => {
                    out.push(ValueId(*n));
                    self.next();
                    if self.peek() == Some(&Tok::Comma) {
                        self.next();
                    } else {
                        break;
                    }
                }
                _ => break,
            }
        }
        Ok(out)
    }

    fn attr_value(&mut self) -> Result<Attr> {
        match self.next() {
            Some(Tok::Int(v)) => Ok(Attr::Int(v)),
            Some(Tok::Float(v)) => Ok(Attr::Float(v)),
            Some(Tok::Str(s)) => Ok(Attr::Str(s)),
            Some(Tok::Ident(s)) if s == "true" => Ok(Attr::Bool(true)),
            Some(Tok::Ident(s)) if s == "false" => Ok(Attr::Bool(false)),
            Some(Tok::LBracket) => {
                let mut items = Vec::new();
                if self.peek() != Some(&Tok::RBracket) {
                    loop {
                        items.push(self.attr_value()?);
                        if self.peek() == Some(&Tok::Comma) {
                            self.next();
                        } else {
                            break;
                        }
                    }
                }
                self.expect(Tok::RBracket)?;
                Ok(Attr::List(items))
            }
            got => Err(self.err(format!("expected attribute value, got {got:?}"))),
        }
    }

    /// Attr dict: `{ k = v, ... }` — caller has checked the lookahead.
    fn attr_dict(&mut self) -> Result<BTreeMap<String, Attr>> {
        self.expect(Tok::LBrace)?;
        let mut out = BTreeMap::new();
        while self.peek() != Some(&Tok::RBrace) {
            let key = match self.next() {
                Some(Tok::Ident(s)) => s,
                got => return Err(self.err(format!("expected attr key, got {got:?}"))),
            };
            self.expect(Tok::Eq)?;
            out.insert(key, self.attr_value()?);
            if self.peek() == Some(&Tok::Comma) {
                self.next();
            }
        }
        self.expect(Tok::RBrace)?;
        Ok(out)
    }

    fn looks_like_attr_dict(&self) -> bool {
        self.peek() == Some(&Tok::LBrace)
            && matches!(self.peek2(), Some(Tok::Ident(s)) if s != "yield")
            && matches!(self.toks.get(self.pos + 2).map(|(t, _)| t), Some(Tok::Eq))
    }

    /// Parse a region body into `g` until the closing brace.
    fn body(&mut self, g: &mut Graph) -> Result<()> {
        loop {
            match self.peek() {
                Some(Tok::RBrace) => {
                    self.next();
                    return Ok(());
                }
                Some(Tok::Ident(s)) if s == "yield" => {
                    self.next();
                    let outputs = self.value_list()?;
                    for v in &outputs {
                        g.reserve_value(*v);
                    }
                    g.outputs = outputs;
                    self.expect(Tok::RBrace)?;
                    return Ok(());
                }
                Some(_) => self.statement(g)?,
                None => return Err(self.err("unexpected end of input in block")),
            }
        }
    }

    fn statement(&mut self, g: &mut Graph) -> Result<()> {
        // Optional result list.
        let mut results = Vec::new();
        if matches!(self.peek(), Some(Tok::Value(_))) {
            results = self.value_list()?;
            self.expect(Tok::Eq)?;
        }
        let op = match self.next() {
            Some(Tok::Ident(s)) => s,
            got => return Err(self.err(format!("expected op name, got {got:?}"))),
        };
        self.expect(Tok::LParen)?;
        let operands = self.value_list()?;
        self.expect(Tok::RParen)?;

        let attrs = if self.looks_like_attr_dict() {
            self.attr_dict()?
        } else if self.peek() == Some(&Tok::LBrace)
            && self.peek2() == Some(&Tok::RBrace)
            && !super::ops::op(&op).map(|o| o.has_region).unwrap_or(false)
        {
            // `{}` on a region-less op: empty attr dict.
            self.next();
            self.next();
            BTreeMap::new()
        } else {
            BTreeMap::new()
        };

        let region = if self.peek() == Some(&Tok::LBrace) {
            self.next();
            let mut sub = Graph::new(&format!("{}_region", op.replace('.', "_")));
            self.body(&mut sub)?;
            Some(sub)
        } else {
            None
        };

        for v in results.iter().chain(operands.iter()) {
            g.reserve_value(*v);
        }
        g.push_node(Node {
            id: NodeId(0), // reassigned by push_node
            op,
            operands,
            results,
            attrs,
            region,
        });
        Ok(())
    }
}

/// Parse IR text into a [`Graph`].
pub fn parse(src: &str) -> Result<Graph> {
    let toks = Lexer::new(src).tokens()?;
    let mut p = Parser { toks, pos: 0 };
    p.expect_ident("graph")?;
    p.expect(Tok::At)?;
    let name = match p.next() {
        Some(Tok::Ident(s)) => s,
        got => return Err(p.err(format!("expected graph name, got {got:?}"))),
    };
    let mut g = Graph::new(&name);
    p.expect(Tok::LParen)?;
    let args = p.value_list()?;
    for v in &args {
        g.reserve_value(*v);
    }
    g.args = args;
    p.expect(Tok::RParen)?;
    p.expect(Tok::LBrace)?;
    p.body(&mut g)?;
    if p.peek().is_some() {
        return Err(p.err("trailing tokens after graph"));
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::printer;

    const VOICE: &str = r#"
// Figure 2's conversational voice agent
graph @voice() {
  %0 = io.input() {modality = "audio"}
  %1 = stt.transcribe(%0) {model = "whisper-small"}
  %2 = llm.infer(%1) {model = "8b-fp16", isl = 512, osl = 256}
  %3 = tts.synthesize(%2)
  io.output(%3)
  yield %3
}
"#;

    #[test]
    fn parses_voice_agent() {
        let g = parse(VOICE).unwrap();
        assert_eq!(g.name, "voice");
        assert_eq!(g.nodes.len(), 5);
        assert_eq!(g.nodes[2].op, "llm.infer");
        assert_eq!(g.nodes[2].attr_int("isl"), Some(512));
        assert_eq!(g.outputs, vec![ValueId(3)]);
    }

    #[test]
    fn round_trip() {
        let g = parse(VOICE).unwrap();
        let text = printer::print(&g);
        let g2 = parse(&text).unwrap();
        assert_eq!(printer::print(&g2), text);
    }

    #[test]
    fn parses_region() {
        let src = r#"
graph @outer() {
  %0 = io.input()
  %1 = ctrl.loop(%0) {max_trips = 3} {
    %0 = io.input()
    %1 = tool.call(%0) {tool = "search"}
    yield %1
  }
  io.output(%1)
}
"#;
        let g = parse(src).unwrap();
        let loop_node = &g.nodes[1];
        assert_eq!(loop_node.op, "ctrl.loop");
        assert_eq!(loop_node.attr_int("max_trips"), Some(3));
        let region = loop_node.region.as_ref().unwrap();
        assert_eq!(region.nodes.len(), 2);
        assert_eq!(region.outputs.len(), 1);
    }

    #[test]
    fn parses_attr_types() {
        let src = r#"
graph @attrs() {
  %0 = io.input() {flag = true, ratio = 0.5, n = -3, tags = ["a", "b"], name = "x"}
  yield %0
}
"#;
        let g = parse(src).unwrap();
        let n = &g.nodes[0];
        assert_eq!(n.attr("flag").unwrap().as_bool(), Some(true));
        assert_eq!(n.attr_f64("ratio"), Some(0.5));
        assert_eq!(n.attr_int("n"), Some(-3));
        assert_eq!(n.attr("tags").unwrap().as_list().unwrap().len(), 2);
    }

    #[test]
    fn error_reports_line() {
        let src = "graph @x() {\n  %0 = io.input()\n  $bad\n}";
        match parse(src) {
            Err(crate::Error::Parse { line, .. }) => assert_eq!(line, 3),
            other => panic!("expected parse error, got {other:?}"),
        }
    }

    #[test]
    fn rejects_trailing_tokens() {
        assert!(parse("graph @x() { yield } extra").is_err());
    }

    #[test]
    fn multi_result_statement() {
        let src = "graph @m() {\n %0 = io.input()\n %1, %2 = llm.prefill(%0)\n yield %1\n}";
        let g = parse(src).unwrap();
        assert_eq!(g.nodes[1].results.len(), 2);
    }
}
