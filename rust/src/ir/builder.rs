//! Fluent construction of agent graphs — the programmatic equivalent of
//! the LangChain-style authoring surface of Figure 7(a).

use std::collections::BTreeMap;

use super::attr::Attr;
use super::graph::{Graph, ValueId};
use super::ops;

/// Builder over a [`Graph`]; ops allocate results automatically from
/// the registry's result arity.
pub struct GraphBuilder {
    graph: Graph,
}

impl GraphBuilder {
    pub fn new(name: &str) -> GraphBuilder {
        GraphBuilder {
            graph: Graph::new(name),
        }
    }

    /// Append `op`; returns its first result (or a dummy for 0-result ops).
    pub fn op(&mut self, op: &str, operands: &[ValueId]) -> ValueId {
        self.op_with(op, operands, &[])
    }

    /// Append `op` with attributes.
    pub fn op_with(
        &mut self,
        op: &str,
        operands: &[ValueId],
        attrs: &[(&str, Attr)],
    ) -> ValueId {
        let n_results = ops::op(op).map(|o| o.results).unwrap_or(1);
        let map: BTreeMap<String, Attr> = attrs
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect();
        let id = self.graph.push(op, operands.to_vec(), n_results, map, None);
        self.graph
            .node(id)
            .unwrap()
            .results
            .first()
            .copied()
            .unwrap_or(ValueId(u32::MAX))
    }

    /// Append `op` returning all results.
    pub fn op_multi(
        &mut self,
        op: &str,
        operands: &[ValueId],
        attrs: &[(&str, Attr)],
    ) -> Vec<ValueId> {
        let n_results = ops::op(op).map(|o| o.results).unwrap_or(1);
        let map: BTreeMap<String, Attr> = attrs
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect();
        let id = self.graph.push(op, operands.to_vec(), n_results, map, None);
        self.graph.node(id).unwrap().results.clone()
    }

    /// Append a region-carrying op (nested agent / loop).
    pub fn region_op(
        &mut self,
        op: &str,
        operands: &[ValueId],
        attrs: &[(&str, Attr)],
        region: Graph,
    ) -> ValueId {
        let n_results = ops::op(op).map(|o| o.results).unwrap_or(1);
        let map: BTreeMap<String, Attr> = attrs
            .iter()
            .map(|(k, v)| (k.to_string(), v.clone()))
            .collect();
        let id = self
            .graph
            .push(op, operands.to_vec(), n_results, map, Some(region));
        self.graph
            .node(id)
            .unwrap()
            .results
            .first()
            .copied()
            .unwrap_or(ValueId(u32::MAX))
    }

    /// Mark region outputs.
    pub fn output(&mut self, v: ValueId) -> &mut Self {
        self.graph.outputs.push(v);
        self
    }

    pub fn finish(self) -> Graph {
        self.graph
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_linear_chain() {
        let mut b = GraphBuilder::new("chain");
        let x = b.op("io.input", &[]);
        let y = b.op_with("llm.infer", &[x], &[("model", "8b-fp16".into())]);
        b.op("io.output", &[y]);
        let g = b.finish();
        assert_eq!(g.nodes.len(), 3);
        assert_eq!(g.nodes[1].attr_str("model"), Some("8b-fp16"));
        assert!(g.is_ssa_ordered(&[]));
    }

    #[test]
    fn multi_result_op() {
        let mut b = GraphBuilder::new("m");
        let x = b.op("io.input", &[]);
        let rs = b.op_multi("llm.prefill", &[x], &[]);
        assert_eq!(rs.len(), 2); // hidden state + kv handle
    }

    #[test]
    fn region_nesting() {
        let mut inner = GraphBuilder::new("sub");
        let i = inner.op("io.input", &[]);
        let o = inner.op("llm.infer", &[i]);
        inner.output(o);
        let inner = inner.finish();

        let mut b = GraphBuilder::new("outer");
        let x = b.op("io.input", &[]);
        let a = b.region_op("agent.graph", &[x], &[("role", "supervisor".into())], inner);
        b.op("io.output", &[a]);
        let g = b.finish();
        assert_eq!(g.size(), 5);
    }
}
