//! The dataflow graph: SSA values, operations, hierarchical regions.
//!
//! "A natural way to express agent workloads is as a directed,
//! potentially cyclic, graph of tasks ... nodes are hierarchical, where
//! the node may itself be an agent composed of further subgraphs"
//! (§2.4). Dataflow edges are SSA operand references (acyclic by
//! construction); cyclic *control* (feedback loops, Figure 2's
//! search-until-satisfied loop) is expressed by `ctrl.loop` regions with
//! a bounded `max_trips` attribute — exactly the "bounded unrolling"
//! §3.1 requires of runtime planning.

use std::collections::BTreeMap;

use super::attr::Attr;

/// A value produced by an operation (or a graph argument).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ValueId(pub u32);

/// A node (operation instance) in one region.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub u32);

/// One operation instance.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    /// Fully-qualified op name ("llm.infer"). Kept as String so parsed
    /// graphs can carry extension ops; the verifier flags unknown names.
    pub op: String,
    pub operands: Vec<ValueId>,
    pub results: Vec<ValueId>,
    pub attrs: BTreeMap<String, Attr>,
    /// Nested region for `has_region` ops (hierarchical agents, loops).
    pub region: Option<Graph>,
}

impl Node {
    pub fn attr(&self, key: &str) -> Option<&Attr> {
        self.attrs.get(key)
    }

    pub fn attr_str(&self, key: &str) -> Option<&str> {
        self.attrs.get(key).and_then(|a| a.as_str())
    }

    pub fn attr_int(&self, key: &str) -> Option<i64> {
        self.attrs.get(key).and_then(|a| a.as_int())
    }

    pub fn attr_f64(&self, key: &str) -> Option<f64> {
        self.attrs.get(key).and_then(|a| a.as_f64())
    }

    pub fn set_attr(&mut self, key: &str, val: impl Into<Attr>) {
        self.attrs.insert(key.to_string(), val.into());
    }
}

/// A region: an ordered list of nodes in SSA form.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    /// Symbol name (`@voice_agent`).
    pub name: String,
    pub nodes: Vec<Node>,
    /// Region arguments (visible as values inside).
    pub args: Vec<ValueId>,
    /// Values yielded by the region.
    pub outputs: Vec<ValueId>,
    next_value: u32,
    next_node: u32,
}

impl Graph {
    pub fn new(name: &str) -> Graph {
        Graph {
            name: name.to_string(),
            ..Default::default()
        }
    }

    pub fn fresh_value(&mut self) -> ValueId {
        let v = ValueId(self.next_value);
        self.next_value += 1;
        v
    }

    /// Ensure the internal counter is past `v` (parser support).
    pub fn reserve_value(&mut self, v: ValueId) {
        if v.0 >= self.next_value {
            self.next_value = v.0 + 1;
        }
    }

    pub fn add_arg(&mut self) -> ValueId {
        let v = self.fresh_value();
        self.args.push(v);
        v
    }

    /// Append an op; results are freshly allocated.
    pub fn push(
        &mut self,
        op: &str,
        operands: Vec<ValueId>,
        n_results: usize,
        attrs: BTreeMap<String, Attr>,
        region: Option<Graph>,
    ) -> NodeId {
        let id = NodeId(self.next_node);
        self.next_node += 1;
        let results = (0..n_results).map(|_| self.fresh_value()).collect();
        self.nodes.push(Node {
            id,
            op: op.to_string(),
            operands,
            results,
            attrs,
            region,
        });
        id
    }

    /// Append a fully-specified node (pass support). Result/value ids
    /// must have been allocated from this graph.
    pub fn push_node(&mut self, mut node: Node) -> NodeId {
        let id = NodeId(self.next_node);
        self.next_node += 1;
        node.id = id;
        for r in &node.results {
            self.reserve_value(*r);
        }
        self.nodes.push(node);
        id
    }

    pub fn node(&self, id: NodeId) -> Option<&Node> {
        self.nodes.iter().find(|n| n.id == id)
    }

    pub fn node_mut(&mut self, id: NodeId) -> Option<&mut Node> {
        self.nodes.iter_mut().find(|n| n.id == id)
    }

    /// The node producing `v`, if any (None for args / outer captures).
    pub fn producer(&self, v: ValueId) -> Option<&Node> {
        self.nodes.iter().find(|n| n.results.contains(&v))
    }

    /// Nodes consuming `v` in this region (not descending into regions).
    pub fn consumers(&self, v: ValueId) -> Vec<&Node> {
        self.nodes
            .iter()
            .filter(|n| n.operands.contains(&v))
            .collect()
    }

    /// Count of uses of `v` in this region (operands + outputs).
    ///
    /// Regions are *closed scopes* — a nested region's values live in
    /// its own namespace and receive outer data only through its region
    /// op's operands — so we do not descend into regions here.
    pub fn use_count(&self, v: ValueId) -> usize {
        let mut n = self.outputs.iter().filter(|o| **o == v).count();
        for node in &self.nodes {
            n += node.operands.iter().filter(|o| **o == v).count();
        }
        n
    }

    /// Replace all uses of `from` with `to` in this region (operands and
    /// outputs; nested regions are closed scopes, see [`use_count`]).
    pub fn replace_uses(&mut self, from: ValueId, to: ValueId) {
        for node in &mut self.nodes {
            for o in &mut node.operands {
                if *o == from {
                    *o = to;
                }
            }
        }
        for o in &mut self.outputs {
            if *o == from {
                *o = to;
            }
        }
    }

    /// Top-level dataflow edges as (producer index, consumer index)
    /// pairs over `self.nodes` order, deduplicated. Nested regions are
    /// closed scopes and contribute no edges here. This is the DAG the
    /// planner binds and the cluster simulator executes per request.
    pub fn dataflow_edges(&self) -> Vec<(usize, usize)> {
        let mut producer_of: BTreeMap<ValueId, usize> = BTreeMap::new();
        for (i, n) in self.nodes.iter().enumerate() {
            for r in &n.results {
                producer_of.insert(*r, i);
            }
        }
        let mut edges = Vec::new();
        for (j, n) in self.nodes.iter().enumerate() {
            for o in &n.operands {
                if let Some(&i) = producer_of.get(o) {
                    if !edges.contains(&(i, j)) {
                        edges.push((i, j));
                    }
                }
            }
        }
        edges
    }

    /// Total node count including nested regions.
    pub fn size(&self) -> usize {
        self.nodes
            .iter()
            .map(|n| 1 + n.region.as_ref().map(|r| r.size()).unwrap_or(0))
            .sum()
    }

    /// Ops used anywhere (for dialect statistics / tests).
    pub fn op_names(&self) -> Vec<String> {
        let mut out = Vec::new();
        for n in &self.nodes {
            out.push(n.op.clone());
            if let Some(r) = &n.region {
                out.extend(r.op_names());
            }
        }
        out
    }

    /// Does any node (recursively) use this op?
    pub fn contains_op(&self, op: &str) -> bool {
        self.nodes
            .iter()
            .any(|n| n.op == op || n.region.as_ref().map(|r| r.contains_op(op)).unwrap_or(false))
    }

    /// Dataflow-order iteration is just `self.nodes` (SSA order). This
    /// validates that property: every operand is an arg or produced by
    /// an earlier node. Nested regions are closed scopes and validate
    /// against their own args only.
    pub fn is_ssa_ordered(&self, outer: &[ValueId]) -> bool {
        let mut defined: Vec<ValueId> = self.args.clone();
        defined.extend_from_slice(outer);
        for n in &self.nodes {
            for o in &n.operands {
                if !defined.contains(o) {
                    return false;
                }
            }
            if let Some(r) = &n.region {
                if !r.is_ssa_ordered(&[]) {
                    return false;
                }
            }
            defined.extend_from_slice(&n.results);
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple_graph() -> Graph {
        let mut g = Graph::new("t");
        let input = g.push("io.input", vec![], 1, BTreeMap::new(), None);
        let v0 = g.node(input).unwrap().results[0];
        let infer = g.push("llm.infer", vec![v0], 1, BTreeMap::new(), None);
        let v1 = g.node(infer).unwrap().results[0];
        g.push("io.output", vec![v1], 0, BTreeMap::new(), None);
        g
    }

    #[test]
    fn push_allocates_fresh_ids() {
        let g = simple_graph();
        assert_eq!(g.nodes.len(), 3);
        assert_eq!(g.nodes[0].results, vec![ValueId(0)]);
        assert_eq!(g.nodes[1].operands, vec![ValueId(0)]);
        assert_eq!(g.nodes[1].results, vec![ValueId(1)]);
    }

    #[test]
    fn producer_and_consumers() {
        let g = simple_graph();
        let v0 = ValueId(0);
        assert_eq!(g.producer(v0).unwrap().op, "io.input");
        let c = g.consumers(v0);
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].op, "llm.infer");
    }

    #[test]
    fn replace_uses_rewires() {
        let mut g = simple_graph();
        let v_new = g.fresh_value();
        g.replace_uses(ValueId(1), v_new);
        assert_eq!(g.nodes[2].operands, vec![v_new]);
    }

    #[test]
    fn use_count_counts_outputs_too() {
        let mut g = simple_graph();
        g.outputs.push(ValueId(1));
        assert_eq!(g.use_count(ValueId(1)), 2); // io.output + graph output
        assert_eq!(g.use_count(ValueId(0)), 1);
    }

    #[test]
    fn ssa_order_valid_and_violated() {
        let g = simple_graph();
        assert!(g.is_ssa_ordered(&[]));

        let mut bad = Graph::new("bad");
        let v_future = ValueId(5);
        bad.reserve_value(v_future);
        bad.push("io.output", vec![v_future], 0, BTreeMap::new(), None);
        assert!(!bad.is_ssa_ordered(&[]));
    }

    #[test]
    fn dataflow_edges_follow_ssa_chain() {
        let g = simple_graph();
        assert_eq!(g.dataflow_edges(), vec![(0, 1), (1, 2)]);
    }

    #[test]
    fn nested_region_size() {
        let mut inner = Graph::new("inner");
        inner.push("io.input", vec![], 1, BTreeMap::new(), None);
        let mut g = Graph::new("outer");
        g.push("agent.graph", vec![], 1, BTreeMap::new(), Some(inner));
        assert_eq!(g.size(), 2);
        assert!(g.contains_op("io.input"));
        assert!(!g.contains_op("llm.infer"));
    }
}
