//! Agent-graph intermediate representation (paper §2.4, §4.2).
//!
//! MLIR itself is a C++ framework unavailable in this offline
//! environment, so this module implements the *semantics* the paper
//! builds on MLIR — a multi-level, dialect-organized, hierarchically
//! nested dataflow IR with a textual round-trip format and a pass
//! pipeline — natively in Rust (see DESIGN.md substitution table):
//!
//! * [`attr`] — attribute values annotating operations (model names,
//!   sequence lengths, profiled resource vectors, placement hints);
//! * [`ops`] — the dialect registry: the Table-1 task types as typed
//!   operations (`llm.infer`, `kv.transfer`, `tool.call`, `gate.select`,
//!   ...), with operand/result arity, purity, region-ness, and the
//!   Figure-3 workload class each op inherits;
//! * [`graph`] — SSA-style dataflow graphs with hierarchical regions
//!   (an `agent.graph` node nests a subgraph — the paper's composite
//!   agent nodes);
//! * [`builder`] — ergonomic construction;
//! * [`printer`] / [`parser`] — the textual format (Fig. 7);
//! * [`verifier`] — structural validation;
//! * [`passes`] — the transformation pipeline: LLM prefill/decode
//!   decomposition, tool decomposition, expert parallelism, fusion,
//!   DCE, canonicalization, and cost annotation.

pub mod attr;
pub mod builder;
pub mod graph;
pub mod ops;
pub mod parser;
pub mod passes;
pub mod printer;
pub mod verifier;

pub use attr::Attr;
pub use builder::GraphBuilder;
pub use graph::{Graph, Node, NodeId, ValueId};
pub use ops::{op, OpInfo};
