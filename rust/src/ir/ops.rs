//! The dialect registry: every operation type the agent IR understands.
//!
//! Dialects mirror the paper's Table 1 task taxonomy plus the Figure 7
//! decomposed forms. Each op carries structural metadata (arity, purity,
//! region-ness) and, where applicable, the Figure-3 [`WorkloadClass`]
//! used by the cost-annotation pass.

use crate::cost::workload::WorkloadClass;

/// Operand arity constraint.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arity {
    Exact(usize),
    AtLeast(usize),
    /// Between (min, max) inclusive.
    Range(usize, usize),
}

impl Arity {
    pub fn check(&self, n: usize) -> bool {
        match self {
            Arity::Exact(k) => n == *k,
            Arity::AtLeast(k) => n >= *k,
            Arity::Range(a, b) => n >= *a && n <= *b,
        }
    }
}

/// Static metadata for one op type.
#[derive(Debug, Clone)]
pub struct OpInfo {
    /// Fully-qualified name, "dialect.op".
    pub name: &'static str,
    pub operands: Arity,
    pub results: usize,
    /// Pure ops with unused results are DCE-able.
    pub pure_op: bool,
    /// Whether the op carries a nested region (hierarchical agents).
    pub has_region: bool,
    /// The Figure-3 workload profile this op inherits for cost
    /// annotation (None = negligible / structural).
    pub workload: Option<WorkloadClass>,
}

/// The registry. Grouped by dialect:
///
/// * `io`     — graph boundary (Figure 2's input/output nodes)
/// * `agent`  — hierarchical/composite agents (Table 1 "Agent")
/// * `llm`    — model execution, whole and disaggregated
/// * `kv`     — KV-cache read/write/transfer (Table 1 "Model KV Cache")
/// * `tool`   — tool calls, whole and decomposed (lookup + compute)
/// * `mem`    — memory/vector-DB lookups (Table 1 "Memory Lookup")
/// * `gp`     — general-purpose CPU compute
/// * `ctrl`   — control flow / planner (Table 1 "Control Flow/Planner")
/// * `obs`    — observation store (Table 1 "Observation Store")
/// * `media`  — modality conversion (Figure 2 voice agent: STT/TTS)
/// * `moe`    — expert-parallel decomposition (Figure 7c)
pub const REGISTRY: &[OpInfo] = &[
    // io
    OpInfo { name: "io.input", operands: Arity::Exact(0), results: 1, pure_op: false, has_region: false, workload: None },
    OpInfo { name: "io.output", operands: Arity::AtLeast(1), results: 0, pure_op: false, has_region: false, workload: None },
    // agent
    OpInfo { name: "agent.graph", operands: Arity::AtLeast(0), results: 1, pure_op: false, has_region: true, workload: None },
    OpInfo { name: "agent.invoke", operands: Arity::AtLeast(1), results: 1, pure_op: false, has_region: false, workload: None },
    // llm
    OpInfo { name: "llm.infer", operands: Arity::AtLeast(1), results: 1, pure_op: true, has_region: false, workload: Some(WorkloadClass::LlmInferenceSingleNode) },
    OpInfo { name: "llm.prefill", operands: Arity::AtLeast(1), results: 2, pure_op: true, has_region: false, workload: Some(WorkloadClass::LlmPrefillDisagg) },
    OpInfo { name: "llm.decode", operands: Arity::AtLeast(2), results: 1, pure_op: true, has_region: false, workload: Some(WorkloadClass::LlmDecodeDisagg) },
    OpInfo { name: "llm.diffuse", operands: Arity::AtLeast(1), results: 1, pure_op: true, has_region: false, workload: Some(WorkloadClass::DiffusionModel) },
    // kv
    OpInfo { name: "kv.write", operands: Arity::Exact(1), results: 1, pure_op: false, has_region: false, workload: Some(WorkloadClass::KvCacheStorage) },
    OpInfo { name: "kv.read", operands: Arity::Exact(1), results: 1, pure_op: true, has_region: false, workload: Some(WorkloadClass::KvCacheStorage) },
    OpInfo { name: "kv.transfer", operands: Arity::Exact(1), results: 1, pure_op: true, has_region: false, workload: Some(WorkloadClass::KvCacheStorage) },
    // tool
    OpInfo { name: "tool.call", operands: Arity::AtLeast(1), results: 1, pure_op: false, has_region: false, workload: Some(WorkloadClass::ToolCall) },
    OpInfo { name: "tool.lookup", operands: Arity::AtLeast(1), results: 1, pure_op: false, has_region: false, workload: Some(WorkloadClass::ToolCall) },
    OpInfo { name: "tool.compute", operands: Arity::AtLeast(1), results: 1, pure_op: true, has_region: false, workload: Some(WorkloadClass::GeneralDataProcessing) },
    // mem
    OpInfo { name: "mem.lookup", operands: Arity::AtLeast(1), results: 1, pure_op: true, has_region: false, workload: Some(WorkloadClass::KvCacheStorage) },
    OpInfo { name: "mem.store", operands: Arity::AtLeast(1), results: 0, pure_op: false, has_region: false, workload: Some(WorkloadClass::KvCacheStorage) },
    // gp
    OpInfo { name: "gp.compute", operands: Arity::AtLeast(1), results: 1, pure_op: true, has_region: false, workload: Some(WorkloadClass::GeneralDataProcessing) },
    // ctrl
    OpInfo { name: "ctrl.branch", operands: Arity::AtLeast(1), results: 1, pure_op: true, has_region: false, workload: None },
    OpInfo { name: "ctrl.loop", operands: Arity::AtLeast(1), results: 1, pure_op: false, has_region: true, workload: None },
    OpInfo { name: "ctrl.plan", operands: Arity::AtLeast(1), results: 1, pure_op: true, has_region: false, workload: Some(WorkloadClass::GeneralDataProcessing) },
    OpInfo { name: "ctrl.merge", operands: Arity::AtLeast(1), results: 1, pure_op: true, has_region: false, workload: None },
    // obs
    OpInfo { name: "obs.store", operands: Arity::AtLeast(1), results: 0, pure_op: false, has_region: false, workload: Some(WorkloadClass::KvCacheStorage) },
    // media
    OpInfo { name: "stt.transcribe", operands: Arity::Exact(1), results: 1, pure_op: true, has_region: false, workload: Some(WorkloadClass::GeneralDataProcessing) },
    OpInfo { name: "tts.synthesize", operands: Arity::Exact(1), results: 1, pure_op: true, has_region: false, workload: Some(WorkloadClass::GeneralDataProcessing) },
    // moe (Figure 7c)
    OpInfo { name: "gate.select", operands: Arity::Exact(1), results: 1, pure_op: true, has_region: false, workload: Some(WorkloadClass::GeneralDataProcessing) },
    OpInfo { name: "moe.expert_prefill", operands: Arity::Exact(1), results: 2, pure_op: true, has_region: false, workload: Some(WorkloadClass::LlmPrefillDisagg) },
    OpInfo { name: "moe.expert_decode", operands: Arity::Exact(2), results: 1, pure_op: true, has_region: false, workload: Some(WorkloadClass::LlmDecodeDisagg) },
    OpInfo { name: "moe.merge", operands: Arity::AtLeast(1), results: 1, pure_op: true, has_region: false, workload: None },
];

/// Look up an op by fully-qualified name.
pub fn op(name: &str) -> Option<&'static OpInfo> {
    REGISTRY.iter().find(|o| o.name == name)
}

/// All ops in a dialect.
pub fn dialect_ops(dialect: &str) -> Vec<&'static OpInfo> {
    REGISTRY
        .iter()
        .filter(|o| o.name.split('.').next() == Some(dialect))
        .collect()
}

/// The dialect of a fully-qualified op name.
pub fn dialect_of(name: &str) -> &str {
    name.split('.').next().unwrap_or("")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_unique_and_qualified() {
        let mut seen = std::collections::BTreeSet::new();
        for o in REGISTRY {
            assert!(o.name.contains('.'), "{} not dialect-qualified", o.name);
            assert!(seen.insert(o.name), "duplicate op {}", o.name);
        }
    }

    #[test]
    fn lookup() {
        assert!(op("llm.infer").is_some());
        assert!(op("llm.prefill").is_some());
        assert!(op("nope.nope").is_none());
    }

    #[test]
    fn table1_task_types_covered() {
        // Agent, Model Execution, KV Cache, Tool Call, Memory Lookup,
        // General Purpose Compute, Control Flow/Planner, Observation Store.
        for name in [
            "agent.graph",
            "llm.infer",
            "kv.read",
            "tool.call",
            "mem.lookup",
            "gp.compute",
            "ctrl.plan",
            "obs.store",
        ] {
            assert!(op(name).is_some(), "missing Table-1 op {name}");
        }
    }

    #[test]
    fn arity_checks() {
        assert!(Arity::Exact(2).check(2));
        assert!(!Arity::Exact(2).check(1));
        assert!(Arity::AtLeast(1).check(5));
        assert!(!Arity::AtLeast(1).check(0));
        assert!(Arity::Range(1, 3).check(3));
        assert!(!Arity::Range(1, 3).check(4));
    }

    #[test]
    fn prefill_yields_hidden_state_and_kv() {
        assert_eq!(op("llm.prefill").unwrap().results, 2);
        assert_eq!(op("llm.decode").unwrap().results, 1);
    }

    #[test]
    fn region_ops() {
        assert!(op("agent.graph").unwrap().has_region);
        assert!(op("ctrl.loop").unwrap().has_region);
        assert!(!op("llm.infer").unwrap().has_region);
    }

    #[test]
    fn workload_classes_follow_fig3() {
        use crate::cost::workload::WorkloadClass as W;
        assert_eq!(op("llm.prefill").unwrap().workload, Some(W::LlmPrefillDisagg));
        assert_eq!(op("llm.decode").unwrap().workload, Some(W::LlmDecodeDisagg));
        assert_eq!(op("tool.call").unwrap().workload, Some(W::ToolCall));
        assert_eq!(op("io.input").unwrap().workload, None);
    }

    #[test]
    fn dialect_listing() {
        let llm = dialect_ops("llm");
        assert_eq!(llm.len(), 4);
        assert_eq!(dialect_of("kv.transfer"), "kv");
    }
}
