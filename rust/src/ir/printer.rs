//! Textual IR emission (round-trips through [`super::parser`]).
//!
//! Format (cf. Figure 7's MLIR listings):
//!
//! ```text
//! graph @voice_agent() {
//!   %0 = io.input() {modality = "audio"}
//!   %1 = stt.transcribe(%0) {model = "whisper-small"}
//!   %2, %3 = llm.prefill(%1) {model = "8b-fp16", isl = 512}
//!   %4 = ctrl.loop(%2) {max_trips = 3} {
//!     ...
//!     yield %7
//!   }
//!   io.output(%4)
//!   yield %4
//! }
//! ```

use std::fmt::Write as _;

use super::graph::Graph;

/// Render a graph as IR text.
pub fn print(g: &Graph) -> String {
    let mut out = String::new();
    let args = g
        .args
        .iter()
        .map(|v| format!("%{}", v.0))
        .collect::<Vec<_>>()
        .join(", ");
    let _ = writeln!(out, "graph @{}({}) {{", g.name, args);
    print_body(g, &mut out, 1);
    out.push_str("}\n");
    out
}

fn print_body(g: &Graph, out: &mut String, depth: usize) {
    let pad = "  ".repeat(depth);
    for n in &g.nodes {
        out.push_str(&pad);
        if !n.results.is_empty() {
            let rs = n
                .results
                .iter()
                .map(|v| format!("%{}", v.0))
                .collect::<Vec<_>>()
                .join(", ");
            let _ = write!(out, "{rs} = ");
        }
        let os = n
            .operands
            .iter()
            .map(|v| format!("%{}", v.0))
            .collect::<Vec<_>>()
            .join(", ");
        let _ = write!(out, "{}({})", n.op, os);
        if !n.attrs.is_empty() {
            let attrs = n
                .attrs
                .iter()
                .map(|(k, v)| format!("{k} = {v}"))
                .collect::<Vec<_>>()
                .join(", ");
            let _ = write!(out, " {{{attrs}}}");
        }
        if let Some(region) = &n.region {
            out.push_str(" {\n");
            print_body(region, out, depth + 1);
            out.push_str(&pad);
            out.push('}');
        }
        out.push('\n');
    }
    if !g.outputs.is_empty() {
        let ys = g
            .outputs
            .iter()
            .map(|v| format!("%{}", v.0))
            .collect::<Vec<_>>()
            .join(", ");
        let _ = writeln!(out, "{pad}yield {ys}");
    }
}

#[cfg(test)]
mod tests {
    use crate::ir::attr::Attr;
    use crate::ir::builder::GraphBuilder;

    #[test]
    fn prints_linear_graph() {
        let mut b = GraphBuilder::new("t");
        let x = b.op("io.input", &[]);
        let y = b.op_with(
            "llm.infer",
            &[x],
            &[("model", Attr::from("8b-fp16")), ("isl", Attr::Int(512))],
        );
        b.op("io.output", &[y]);
        b.output(y);
        let text = super::print(&b.finish());
        assert!(text.contains("graph @t() {"));
        assert!(text.contains("%0 = io.input()"));
        assert!(text.contains("%1 = llm.infer(%0) {isl = 512, model = \"8b-fp16\"}"));
        assert!(text.contains("io.output(%1)"));
        assert!(text.contains("yield %1"));
    }

    #[test]
    fn prints_region() {
        let mut inner = GraphBuilder::new("sub");
        let i = inner.op("io.input", &[]);
        inner.output(i);
        let inner = inner.finish();
        let mut b = GraphBuilder::new("outer");
        let x = b.op("io.input", &[]);
        b.region_op("ctrl.loop", &[x], &[("max_trips", Attr::Int(3))], inner);
        let text = super::print(&b.finish());
        assert!(text.contains("ctrl.loop(%0) {max_trips = 3} {"));
        assert!(text.contains("    yield %0"), "{text}");
    }
}
