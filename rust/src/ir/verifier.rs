//! Structural verification of agent graphs.
//!
//! Checks (each returns a descriptive [`crate::Error::Verify`]):
//!
//! 1. every op name is registered (no silent typos);
//! 2. operand/result arity matches the registry;
//! 3. SSA dominance: operands defined before use, within region scope;
//! 4. region presence matches the op (`agent.graph` must carry one,
//!    `llm.infer` must not);
//! 5. no duplicate value definitions;
//! 6. region outputs are defined inside the region;
//! 7. `ctrl.loop` carries a bounded `max_trips` (the §3.1 "bounded
//!    unrolling" precondition for planning cyclic graphs).

use std::collections::BTreeSet;

use super::graph::{Graph, ValueId};
use super::ops;
use crate::{Error, Result};

/// Verify a top-level graph.
pub fn verify(g: &Graph) -> Result<()> {
    verify_region(g, &format!("@{}", g.name))
}

fn verify_region(g: &Graph, path: &str) -> Result<()> {
    let mut defined: BTreeSet<ValueId> = g.args.iter().copied().collect();

    for n in &g.nodes {
        let loc = format!("{path}/{}#{}", n.op, n.id.0);
        let info = ops::op(&n.op)
            .ok_or_else(|| Error::Verify(format!("{loc}: unknown op `{}`", n.op)))?;

        if !info.operands.check(n.operands.len()) {
            return Err(Error::Verify(format!(
                "{loc}: operand count {} violates arity {:?}",
                n.operands.len(),
                info.operands
            )));
        }
        if n.results.len() != info.results {
            return Err(Error::Verify(format!(
                "{loc}: has {} results, op defines {}",
                n.results.len(),
                info.results
            )));
        }
        for o in &n.operands {
            if !defined.contains(o) {
                return Err(Error::Verify(format!(
                    "{loc}: operand %{} used before definition",
                    o.0
                )));
            }
        }
        for r in &n.results {
            if !defined.insert(*r) {
                return Err(Error::Verify(format!(
                    "{loc}: value %{} defined twice",
                    r.0
                )));
            }
        }
        match (&n.region, info.has_region) {
            (None, true) => {
                return Err(Error::Verify(format!("{loc}: missing region")));
            }
            (Some(_), false) => {
                return Err(Error::Verify(format!("{loc}: unexpected region")));
            }
            (Some(r), true) => {
                if n.op == "ctrl.loop" {
                    match n.attr_int("max_trips") {
                        Some(t) if t > 0 => {}
                        _ => {
                            return Err(Error::Verify(format!(
                                "{loc}: ctrl.loop requires positive `max_trips` \
                                 (bounded unrolling)"
                            )))
                        }
                    }
                }
                verify_region(r, &loc)?;
            }
            (None, false) => {}
        }
    }

    for o in &g.outputs {
        if !defined.contains(o) {
            return Err(Error::Verify(format!(
                "{path}: yielded value %{} not defined",
                o.0
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::GraphBuilder;
    use crate::ir::parser::parse;

    fn ok(src: &str) {
        verify(&parse(src).unwrap()).unwrap();
    }

    fn fails_with(src: &str, needle: &str) {
        let err = verify(&parse(src).unwrap()).unwrap_err().to_string();
        assert!(err.contains(needle), "error {err:?} missing {needle:?}");
    }

    #[test]
    fn valid_graph_passes() {
        ok(r#"
graph @g() {
  %0 = io.input()
  %1 = llm.infer(%0) {model = "8b-fp16"}
  io.output(%1)
  yield %1
}
"#);
    }

    #[test]
    fn unknown_op_rejected() {
        fails_with("graph @g() {\n %0 = zzz.whatever()\n}", "unknown op");
    }

    #[test]
    fn arity_violation_rejected() {
        // stt.transcribe requires exactly one operand.
        fails_with(
            "graph @g() {\n %0 = io.input()\n %1 = stt.transcribe()\n}",
            "arity",
        );
    }

    #[test]
    fn result_count_rejected() {
        fails_with(
            "graph @g() {\n %0 = io.input()\n %1 = llm.prefill(%0)\n}",
            "results",
        );
    }

    #[test]
    fn use_before_def_rejected() {
        fails_with(
            "graph @g() {\n %0 = llm.infer(%9)\n}",
            "before definition",
        );
    }

    #[test]
    fn double_definition_rejected() {
        fails_with(
            "graph @g() {\n %0 = io.input()\n %0 = io.input()\n}",
            "defined twice",
        );
    }

    #[test]
    fn undefined_yield_rejected() {
        fails_with("graph @g() {\n yield %3\n}", "not defined");
    }

    #[test]
    fn loop_needs_max_trips() {
        fails_with(
            r#"
graph @g() {
  %0 = io.input()
  %1 = ctrl.loop(%0) {
    %0 = io.input()
    yield %0
  }
}
"#,
            "max_trips",
        );
    }

    #[test]
    fn region_on_regionless_op_rejected() {
        let mut inner = GraphBuilder::new("r");
        let v = inner.op("io.input", &[]);
        inner.output(v);
        let mut b = GraphBuilder::new("g");
        let x = b.op("io.input", &[]);
        b.region_op("llm.infer", &[x], &[], inner.finish());
        let err = verify(&b.finish()).unwrap_err().to_string();
        assert!(err.contains("unexpected region"), "{err}");
    }

    #[test]
    fn missing_region_rejected() {
        // agent.graph without region (built by hand).
        let mut b = GraphBuilder::new("g");
        b.op("agent.graph", &[]);
        let err = verify(&b.finish()).unwrap_err().to_string();
        assert!(err.contains("missing region"), "{err}");
    }

    #[test]
    fn nested_region_verified() {
        fails_with(
            r#"
graph @g() {
  %0 = io.input()
  %1 = ctrl.loop(%0) {max_trips = 2} {
    %0 = zzz.nope()
    yield %0
  }
}
"#,
            "unknown op",
        );
    }
}
