//! Operation attributes.
//!
//! "Each operation can be annotated with profiling metadata, resource
//! usage estimates, or placement hints" (§4.2) — attributes carry all
//! three, plus the structural parameters passes need (sequence lengths,
//! expert counts, precision).

use std::fmt;

/// An attribute value.
#[derive(Debug, Clone, PartialEq)]
pub enum Attr {
    Int(i64),
    Float(f64),
    Str(String),
    Bool(bool),
    /// Homogeneous list (e.g. shapes, per-resource demand vectors).
    List(Vec<Attr>),
}

impl Attr {
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Attr::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Attr::Float(v) => Some(*v),
            Attr::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Attr::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Attr::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_list(&self) -> Option<&[Attr]> {
        match self {
            Attr::List(v) => Some(v),
            _ => None,
        }
    }
}

impl fmt::Display for Attr {
    /// Textual-format rendering (round-trips through the parser).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Attr::Int(v) => write!(f, "{v}"),
            Attr::Float(v) => {
                if v.fract() == 0.0 && v.is_finite() {
                    write!(f, "{v:.1}")
                } else {
                    write!(f, "{v}")
                }
            }
            Attr::Str(s) => write!(f, "\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
            Attr::Bool(b) => write!(f, "{b}"),
            Attr::List(xs) => {
                write!(f, "[")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

impl From<i64> for Attr {
    fn from(v: i64) -> Attr {
        Attr::Int(v)
    }
}
impl From<u64> for Attr {
    fn from(v: u64) -> Attr {
        Attr::Int(v as i64)
    }
}
impl From<u32> for Attr {
    fn from(v: u32) -> Attr {
        Attr::Int(v as i64)
    }
}
impl From<f64> for Attr {
    fn from(v: f64) -> Attr {
        Attr::Float(v)
    }
}
impl From<&str> for Attr {
    fn from(v: &str) -> Attr {
        Attr::Str(v.to_string())
    }
}
impl From<String> for Attr {
    fn from(v: String) -> Attr {
        Attr::Str(v)
    }
}
impl From<bool> for Attr {
    fn from(v: bool) -> Attr {
        Attr::Bool(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        assert_eq!(Attr::Int(3).as_int(), Some(3));
        assert_eq!(Attr::Int(3).as_f64(), Some(3.0));
        assert_eq!(Attr::Float(2.5).as_f64(), Some(2.5));
        assert_eq!(Attr::Str("x".into()).as_str(), Some("x"));
        assert_eq!(Attr::Bool(true).as_bool(), Some(true));
        assert_eq!(Attr::Str("x".into()).as_int(), None);
    }

    #[test]
    fn display_round_trip_shapes() {
        assert_eq!(Attr::Int(42).to_string(), "42");
        assert_eq!(Attr::Float(2.0).to_string(), "2.0");
        assert_eq!(Attr::Float(0.25).to_string(), "0.25");
        assert_eq!(Attr::Str("a\"b".into()).to_string(), "\"a\\\"b\"");
        assert_eq!(
            Attr::List(vec![Attr::Int(1), Attr::Int(2)]).to_string(),
            "[1, 2]"
        );
    }
}
