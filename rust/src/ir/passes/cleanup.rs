//! Cleanup passes: canonicalization, general-purpose-compute fusion
//! ("adjacent or dependent operations can be fused to reduce
//! communication overhead", §4.2), and dead-code elimination.

use super::{for_each_region, Pass};
use crate::ir::attr::Attr;
use crate::ir::graph::Graph;
use crate::Result;

/// Canonicalize:
/// * drop `gp.compute {op = "identity"}` (forward its operand);
/// * collapse `kv.transfer(kv.transfer(x))` chains to a single hop.
pub struct Canonicalize;

impl Pass for Canonicalize {
    fn name(&self) -> &'static str {
        "canonicalize"
    }

    fn run(&self, g: &mut Graph) -> Result<bool> {
        for_each_region(g, &mut |g| {
            let mut changed = false;

            // Identity elimination.
            loop {
                let Some(idx) = g.nodes.iter().position(|n| {
                    n.op == "gp.compute"
                        && n.attr_str("op") == Some("identity")
                        && n.operands.len() == 1
                }) else {
                    break;
                };
                let src = g.nodes[idx].operands[0];
                let dst = g.nodes[idx].results[0];
                g.nodes.remove(idx);
                g.replace_uses(dst, src);
                changed = true;
            }

            // kv.transfer chain collapse: transfer(b) where b = transfer(a)
            // and b is only used once.
            loop {
                let mut rewrite: Option<(usize, crate::ir::graph::ValueId)> = None;
                for (i, n) in g.nodes.iter().enumerate() {
                    if n.op != "kv.transfer" {
                        continue;
                    }
                    let src = n.operands[0];
                    if let Some(prod) = g.producer(src) {
                        if prod.op == "kv.transfer" && g.use_count(src) == 1 {
                            rewrite = Some((i, prod.operands[0]));
                            break;
                        }
                    }
                }
                let Some((i, base)) = rewrite else { break };
                let mid = g.nodes[i].operands[0];
                g.nodes[i].operands[0] = base;
                // The intermediate transfer becomes dead; DCE removes it.
                let _ = mid;
                changed = true;
            }

            Ok(changed)
        })
    }
}

/// Fuse chains of single-use `gp.compute` into one node (attr `fused`
/// records the collapsed stages).
pub struct FuseGpCompute;

impl Pass for FuseGpCompute {
    fn name(&self) -> &'static str {
        "fuse-gp-compute"
    }

    fn run(&self, g: &mut Graph) -> Result<bool> {
        for_each_region(g, &mut |g| {
            let mut changed = false;
            loop {
                // Find b = gp.compute(a) where a = gp.compute(...) and a
                // has exactly one use.
                let mut found: Option<(usize, usize)> = None;
                for (bi, b) in g.nodes.iter().enumerate() {
                    if b.op != "gp.compute" || b.operands.len() != 1 {
                        continue;
                    }
                    let a_val = b.operands[0];
                    if g.use_count(a_val) != 1 {
                        continue;
                    }
                    if let Some(ai) = g
                        .nodes
                        .iter()
                        .position(|n| n.op == "gp.compute" && n.results.contains(&a_val))
                    {
                        found = Some((ai, bi));
                        break;
                    }
                }
                let Some((ai, bi)) = found else { break };
                changed = true;

                let a = g.nodes[ai].clone();
                let stages_a = match a.attr("fused") {
                    Some(Attr::List(xs)) => xs.clone(),
                    _ => vec![Attr::Str(
                        a.attr_str("op").unwrap_or("gp").to_string(),
                    )],
                };
                let b = &mut g.nodes[bi];
                let mut stages = stages_a;
                stages.push(Attr::Str(
                    b.attr_str("op").unwrap_or("gp").to_string(),
                ));
                b.operands = a.operands.clone();
                b.set_attr("fused", Attr::List(stages));
                g.nodes.remove(ai);
            }
            Ok(changed)
        })
    }
}

/// Remove pure nodes whose results are all unused, to fixpoint.
pub struct Dce;

impl Pass for Dce {
    fn name(&self) -> &'static str {
        "dce"
    }

    fn run(&self, g: &mut Graph) -> Result<bool> {
        for_each_region(g, &mut |g| {
            let mut changed = false;
            loop {
                let Some(idx) = g.nodes.iter().position(|n| {
                    crate::ir::ops::op(&n.op)
                        .map(|o| o.pure_op)
                        .unwrap_or(false)
                        && n.results.iter().all(|r| g.use_count(*r) == 0)
                }) else {
                    break;
                };
                g.nodes.remove(idx);
                changed = true;
            }
            Ok(changed)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse;
    use crate::ir::verifier::verify;

    #[test]
    fn identity_elimination() {
        let mut g = parse(
            r#"
graph @g() {
  %0 = io.input()
  %1 = gp.compute(%0) {op = "identity"}
  io.output(%1)
}
"#,
        )
        .unwrap();
        assert!(Canonicalize.run(&mut g).unwrap());
        verify(&g).unwrap();
        assert_eq!(g.nodes.len(), 2);
        assert_eq!(g.nodes[1].operands[0], g.nodes[0].results[0]);
    }

    #[test]
    fn transfer_chain_collapsed_then_dce() {
        let mut g = parse(
            r#"
graph @g() {
  %0 = io.input()
  %1 = kv.transfer(%0)
  %2 = kv.transfer(%1)
  io.output(%2)
}
"#,
        )
        .unwrap();
        assert!(Canonicalize.run(&mut g).unwrap());
        assert!(Dce.run(&mut g).unwrap());
        verify(&g).unwrap();
        let names = g.op_names();
        assert_eq!(
            names.iter().filter(|o| *o == "kv.transfer").count(),
            1,
            "{names:?}"
        );
    }

    #[test]
    fn gp_fusion_merges_chain() {
        let mut g = parse(
            r#"
graph @g() {
  %0 = io.input()
  %1 = gp.compute(%0) {op = "parse_json"}
  %2 = gp.compute(%1) {op = "privacy_filter"}
  %3 = gp.compute(%2) {op = "format"}
  io.output(%3)
}
"#,
        )
        .unwrap();
        assert!(FuseGpCompute.run(&mut g).unwrap());
        verify(&g).unwrap();
        let gp: Vec<_> = g.nodes.iter().filter(|n| n.op == "gp.compute").collect();
        assert_eq!(gp.len(), 1);
        let fused = gp[0].attr("fused").unwrap().as_list().unwrap();
        assert_eq!(fused.len(), 3);
        assert_eq!(fused[0].as_str(), Some("parse_json"));
        assert_eq!(fused[2].as_str(), Some("format"));
    }

    #[test]
    fn fusion_respects_fanout() {
        // %1 used twice -> must NOT fuse.
        let mut g = parse(
            r#"
graph @g() {
  %0 = io.input()
  %1 = gp.compute(%0) {op = "parse"}
  %2 = gp.compute(%1) {op = "a"}
  %3 = gp.compute(%1) {op = "b"}
  io.output(%2, %3)
}
"#,
        )
        .unwrap();
        FuseGpCompute.run(&mut g).unwrap();
        verify(&g).unwrap();
        let gp_count = g.nodes.iter().filter(|n| n.op == "gp.compute").count();
        assert_eq!(gp_count, 3);
    }

    #[test]
    fn dce_removes_unused_pure_keeps_effectful() {
        let mut g = parse(
            r#"
graph @g() {
  %0 = io.input()
  %1 = llm.infer(%0) {model = "8b-fp16"}
  %2 = mem.lookup(%0)
  obs.store(%0)
  io.output(%1)
}
"#,
        )
        .unwrap();
        assert!(Dce.run(&mut g).unwrap());
        verify(&g).unwrap();
        assert!(!g.contains_op("mem.lookup"), "unused pure op removed");
        assert!(g.contains_op("obs.store"), "effectful op kept");
        assert!(g.contains_op("llm.infer"), "used op kept");
    }

    #[test]
    fn dce_cascades() {
        let mut g = parse(
            r#"
graph @g() {
  %0 = io.input()
  %1 = gp.compute(%0) {op = "a"}
  %2 = gp.compute(%1) {op = "b"}
  io.output(%0)
}
"#,
        )
        .unwrap();
        assert!(Dce.run(&mut g).unwrap());
        assert_eq!(g.nodes.len(), 2); // both dead gp.computes removed
    }
}
