//! Agent inlining: flatten `agent.graph` regions into the parent graph.
//!
//! The paper's hierarchical agents (§2.4: "nodes are hierarchical,
//! where the node may itself be an agent composed of further
//! subgraphs") are convenient to author but opaque to the optimizer —
//! a nested supervisor is one assignment variable instead of many.
//! Inlining exposes the inner tasks so the §3.1.2 solver can place each
//! on its own hardware class (MLIR's `inline` + `flatten` analog).
//!
//! Region calling convention (see `graph.rs`): regions are closed
//! scopes; the region's `io.input` ops stand for the op's operands (in
//! order), and the region's yields become the op's results.

use super::{for_each_region, Pass};
use crate::ir::graph::{Graph, Node, NodeId, ValueId};
use crate::Result;

/// Inline every `agent.graph` node (recursively, innermost-first via
/// [`for_each_region`] post-order).
pub struct InlineAgents;

impl Pass for InlineAgents {
    fn name(&self) -> &'static str {
        "inline-agents"
    }

    fn run(&self, g: &mut Graph) -> Result<bool> {
        for_each_region(g, &mut |g| {
            let mut changed = false;
            loop {
                let Some(idx) = g
                    .nodes
                    .iter()
                    .position(|n| n.op == "agent.graph" && n.region.is_some())
                else {
                    break;
                };
                changed = true;
                let agent = g.nodes.remove(idx);
                let region = agent.region.expect("checked above");

                // Map region-local values to parent values.
                let mut map: std::collections::BTreeMap<ValueId, ValueId> =
                    std::collections::BTreeMap::new();
                // Region args (if declared) bind to op operands.
                for (arg, op_operand) in region.args.iter().zip(&agent.operands) {
                    map.insert(*arg, *op_operand);
                }

                let mut inlined: Vec<Node> = Vec::new();
                let mut input_cursor = 0usize;
                for inner in region.nodes {
                    if inner.op == "io.input" {
                        // Bind to the next outer operand.
                        let outer = agent
                            .operands
                            .get(input_cursor)
                            .copied()
                            .unwrap_or_else(|| {
                                // No operand to bind: keep as a fresh
                                // boundary input in the parent.
                                ValueId(u32::MAX)
                            });
                        input_cursor += 1;
                        if outer != ValueId(u32::MAX) {
                            for r in &inner.results {
                                map.insert(*r, outer);
                            }
                            continue; // drop the io.input node
                        }
                    }
                    // Remap operands; allocate fresh parent values for
                    // results.
                    let operands = inner
                        .operands
                        .iter()
                        .map(|o| map.get(o).copied().unwrap_or(*o))
                        .collect();
                    let results: Vec<ValueId> = inner
                        .results
                        .iter()
                        .map(|r| {
                            let nv = g.fresh_value();
                            map.insert(*r, nv);
                            nv
                        })
                        .collect();
                    let mut region2 = inner.region;
                    // Nested regions are closed; nothing to remap inside.
                    inlined.push(Node {
                        id: NodeId(0),
                        op: inner.op,
                        operands,
                        results,
                        attrs: inner.attrs,
                        region: region2.take(),
                    });
                }

                // The agent op's results alias the region's yields.
                for (res, yielded) in agent.results.iter().zip(&region.outputs) {
                    let mapped = map.get(yielded).copied().unwrap_or(*yielded);
                    g.replace_uses(*res, mapped);
                }

                // Splice inlined nodes at the agent's position.
                for (k, node) in inlined.into_iter().enumerate() {
                    g.nodes.insert(idx + k, node);
                }
                // Re-number node ids in order.
                let nodes = std::mem::take(&mut g.nodes);
                for n in nodes {
                    g.push_node(n);
                }
            }
            Ok(changed)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents::patterns;
    use crate::ir::passes::PassManager;
    use crate::ir::verifier::verify;

    #[test]
    fn supervisor_flattens_to_single_region() {
        let mut g = patterns::supervisor("8b-fp16", 3);
        let before_llms = g.op_names().iter().filter(|o| *o == "llm.infer").count();
        assert!(InlineAgents.run(&mut g).unwrap());
        verify(&g).unwrap();
        assert!(!g.contains_op("agent.graph"));
        // All worker LLMs now live in the top region.
        let top_llms = g.nodes.iter().filter(|n| n.op == "llm.infer").count();
        assert_eq!(top_llms, before_llms);
        assert!(g.is_ssa_ordered(&[]));
    }

    #[test]
    fn hierarchical_inlines_recursively() {
        let mut g = patterns::hierarchical("8b-fp16", 2, 2);
        assert!(InlineAgents.run(&mut g).unwrap());
        verify(&g).unwrap();
        assert!(!g.contains_op("agent.graph"));
        // 2 levels × fanout 2 = 4 leaf LLMs, all flattened to the top.
        let llms = g.nodes.iter().filter(|n| n.op == "llm.infer").count();
        assert_eq!(llms, 4);
    }

    #[test]
    fn inlined_graph_plans_with_more_tasks() {
        use crate::opt::assignment::Sla;
        use crate::planner::plan::{Planner, PlannerConfig};

        let g = patterns::supervisor("8b-fp16", 2);
        // The graph as authored hides 2 worker LLMs inside agent.graph
        // regions; the standard pipeline (which now inlines first) must
        // surface them as independent placement decisions.
        let top_level_llms = g.nodes.iter().filter(|n| n.op == "llm.infer").count();
        assert_eq!(top_level_llms, 1, "only the merge LLM is top-level");

        let planner = Planner::new(PlannerConfig {
            sla: Sla::None,
            ..Default::default()
        });
        let plan = planner.plan(&g).unwrap();
        // Each inner LLM got inlined, decomposed, and placed on an
        // accelerator: 2 workers + the supervisor-merge LLM.
        let prefills: Vec<_> = plan
            .bindings
            .iter()
            .filter(|b| b.op == "llm.prefill")
            .collect();
        assert_eq!(prefills.len(), 3, "{:?}", plan.bindings);
        for b in prefills {
            assert_ne!(b.class, "CPU");
        }
        assert!(!plan.bindings.iter().any(|b| b.op == "agent.graph"));
    }

    #[test]
    fn idempotent_on_flat_graphs() {
        let mut g = crate::agents::voice_agent("8b-fp16", 128, 32);
        // voice agent has a ctrl.loop region but no agent.graph.
        assert!(!InlineAgents.run(&mut g).unwrap());
    }

    #[test]
    fn works_inside_standard_pipeline_prefix() {
        let mut g = patterns::agent_as_tool("8b-fp16");
        InlineAgents.run(&mut g).unwrap();
        let mut pm = PassManager::standard();
        pm.run(&mut g).unwrap();
        verify(&g).unwrap();
        assert!(g.contains_op("llm.prefill"));
        assert!(!g.contains_op("agent.graph"));
    }
}
