//! Expert-parallel decomposition (paper Figure 7c).
//!
//! "This is made explicit via a gate.select operation that routes input
//! tokens to top-k experts. Each expert is then executed in parallel
//! using expert.tp.prefill and expert.tp.decode, indicating a
//! tensor-parallel subgraph per expert."
//!
//! `llm.prefill {experts = N, top_k = k}` becomes:
//!
//! ```text
//! %g        = gate.select(%x) {top_k = k, experts = N}
//! %h_i,%kv_i = moe.expert_prefill(%g) {expert = i, tp = ...}   × N
//! %h        = moe.merge(%h_0 ... %h_{N-1})
//! %kv       = moe.merge(%kv_0 ... %kv_{N-1}) {kind = "kv"}
//! ```

use std::collections::BTreeMap;

use super::{for_each_region, Pass};
use crate::ir::attr::Attr;
use crate::ir::graph::{Graph, Node, NodeId};
use crate::Result;

pub struct ExpertParallel;

impl Pass for ExpertParallel {
    fn name(&self) -> &'static str {
        "expert-parallel"
    }

    fn run(&self, g: &mut Graph) -> Result<bool> {
        for_each_region(g, &mut |g| {
            let mut changed = false;
            let nodes = std::mem::take(&mut g.nodes);
            let mut out = Vec::with_capacity(nodes.len());
            for node in nodes {
                let experts = node.attr_int("experts").unwrap_or(1);
                if node.op != "llm.prefill" || experts <= 1 {
                    out.push(node);
                    continue;
                }
                changed = true;
                let top_k = node.attr_int("top_k").unwrap_or(2);
                let (h_out, kv_out) = (node.results[0], node.results[1]);

                // gate.select routes tokens to top-k experts.
                let gated = g.fresh_value();
                let mut gate_attrs = BTreeMap::new();
                gate_attrs.insert("experts".into(), Attr::Int(experts));
                gate_attrs.insert("top_k".into(), Attr::Int(top_k));
                out.push(Node {
                    id: NodeId(0),
                    op: "gate.select".into(),
                    operands: node.operands.clone(),
                    results: vec![gated],
                    attrs: gate_attrs,
                    region: None,
                });

                // One tensor-parallel subtask per expert.
                let mut h_parts = Vec::new();
                let mut kv_parts = Vec::new();
                for e in 0..experts {
                    let h = g.fresh_value();
                    let kv = g.fresh_value();
                    let mut attrs = node.attrs.clone();
                    attrs.remove("experts");
                    attrs.insert("expert".into(), Attr::Int(e));
                    // Each expert handles ~top_k/N of the tokens.
                    attrs.insert(
                        "token_fraction".into(),
                        Attr::Float(top_k as f64 / experts as f64),
                    );
                    out.push(Node {
                        id: NodeId(0),
                        op: "moe.expert_prefill".into(),
                        operands: vec![gated],
                        results: vec![h, kv],
                        attrs,
                        region: None,
                    });
                    h_parts.push(h);
                    kv_parts.push(kv);
                }

                // Merge hidden states and KV handles.
                out.push(Node {
                    id: NodeId(0),
                    op: "moe.merge".into(),
                    operands: h_parts,
                    results: vec![h_out],
                    attrs: BTreeMap::new(),
                    region: None,
                });
                let mut kv_attrs = BTreeMap::new();
                kv_attrs.insert("kind".into(), Attr::Str("kv".into()));
                out.push(Node {
                    id: NodeId(0),
                    op: "moe.merge".into(),
                    operands: kv_parts,
                    results: vec![kv_out],
                    attrs: kv_attrs,
                    region: None,
                });
            }
            g.nodes.clear();
            for n in out {
                g.push_node(n);
            }
            Ok(changed)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse;
    use crate::ir::passes::decompose::DecomposeLlm;
    use crate::ir::verifier::verify;

    #[test]
    fn moe_prefill_expands_to_gate_and_experts() {
        let mut g = parse(
            r#"
graph @g() {
  %0 = io.input()
  %1 = llm.infer(%0) {model = "8b-fp16", experts = 4, top_k = 2}
  io.output(%1)
}
"#,
        )
        .unwrap();
        DecomposeLlm.run(&mut g).unwrap();
        assert!(ExpertParallel.run(&mut g).unwrap());
        verify(&g).unwrap();
        let names = g.op_names();
        assert_eq!(names.iter().filter(|o| *o == "gate.select").count(), 1);
        assert_eq!(
            names.iter().filter(|o| *o == "moe.expert_prefill").count(),
            4
        );
        assert_eq!(names.iter().filter(|o| *o == "moe.merge").count(), 2);
        // Decode side untouched (still consumes merged kv).
        assert!(g.contains_op("llm.decode"));
        // Each expert sees its token fraction.
        let e0 = g
            .nodes
            .iter()
            .find(|n| n.op == "moe.expert_prefill")
            .unwrap();
        assert_eq!(e0.attr_f64("token_fraction"), Some(0.5));
    }

    #[test]
    fn dense_prefill_untouched() {
        let mut g = parse(
            r#"
graph @g() {
  %0 = io.input()
  %1, %2 = llm.prefill(%0) {model = "8b-fp16"}
  io.output(%1)
}
"#,
        )
        .unwrap();
        assert!(!ExpertParallel.run(&mut g).unwrap());
        assert!(!g.contains_op("gate.select"));
    }
}
