//! IR transformation pipeline (paper §4.2 "MLIR for Agentic Workload
//! Planning": fusion & decomposition, static analysis for scheduling,
//! target-aware preparation).
//!
//! * [`inline`] — flatten nested `agent.graph` regions so the optimizer
//!   sees every inner task (hierarchical agents, Fig. 1 c/d/e);
//! * [`decompose`] — `llm.infer` → `llm.prefill` + `kv.transfer` +
//!   `llm.decode` (Figure 7c's disaggregation) and `tool.call` →
//!   `tool.lookup` + `tool.compute`;
//! * [`expert`] — expert parallelism: `gate.select` + per-expert
//!   `moe.expert_*` + `moe.merge` (Figure 7c's hybrid parallelism);
//! * [`cleanup`] — fusion of adjacent general-purpose compute, dead-code
//!   elimination, canonicalization;
//! * [`annotate`] — cost annotation: workload class, Figure-3 demand
//!   vectors, and analytic FLOP/byte estimates per node — the `θ_ij`
//!   extraction that "feed[s] directly into the convex optimization
//!   framework and scheduler".

pub mod annotate;
pub mod cleanup;
pub mod decompose;
pub mod expert;
pub mod inline;

use super::graph::Graph;
use crate::Result;

/// A graph-to-graph transformation.
pub trait Pass {
    fn name(&self) -> &'static str;
    /// Returns true if the graph changed.
    fn run(&self, g: &mut Graph) -> Result<bool>;
}

/// Runs passes in order, optionally verifying after each.
pub struct PassManager {
    passes: Vec<Box<dyn Pass>>,
    pub verify_each: bool,
    /// (pass name, changed) log of the last run.
    pub log: Vec<(String, bool)>,
}

impl PassManager {
    pub fn new() -> PassManager {
        PassManager {
            passes: Vec::new(),
            verify_each: true,
            log: Vec::new(),
        }
    }

    /// The standard lowering pipeline used by the planner: decompose to
    /// granular ops, expose expert parallelism, clean up, annotate.
    pub fn standard() -> PassManager {
        let mut pm = PassManager::new();
        pm.add(inline::InlineAgents)
            .add(decompose::DecomposeLlm)
            .add(decompose::DecomposeTool)
            .add(expert::ExpertParallel)
            .add(cleanup::Canonicalize)
            .add(cleanup::FuseGpCompute)
            .add(cleanup::Dce)
            .add(annotate::AnnotateCost::default());
        pm
    }

    pub fn add(&mut self, pass: impl Pass + 'static) -> &mut Self {
        self.passes.push(Box::new(pass));
        self
    }

    pub fn run(&mut self, g: &mut Graph) -> Result<()> {
        self.log.clear();
        for pass in &self.passes {
            let changed = pass.run(g)?;
            self.log.push((pass.name().to_string(), changed));
            if self.verify_each {
                super::verifier::verify(g)?;
            }
        }
        Ok(())
    }
}

impl Default for PassManager {
    fn default() -> Self {
        Self::new()
    }
}

/// Apply `f` to this graph and every nested region (post-order).
pub fn for_each_region<F: FnMut(&mut Graph) -> Result<bool>>(
    g: &mut Graph,
    f: &mut F,
) -> Result<bool> {
    let mut changed = false;
    for n in &mut g.nodes {
        if let Some(r) = &mut n.region {
            changed |= for_each_region(r, f)?;
        }
    }
    changed |= f(g)?;
    Ok(changed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse;

    #[test]
    fn standard_pipeline_runs_and_logs() {
        let mut g = parse(
            r#"
graph @g() {
  %0 = io.input()
  %1 = llm.infer(%0) {model = "8b-fp16", isl = 512, osl = 128}
  %2 = tool.call(%1) {tool = "search"}
  io.output(%2)
  yield %2
}
"#,
        )
        .unwrap();
        let mut pm = PassManager::standard();
        pm.run(&mut g).unwrap();
        assert_eq!(pm.log.len(), 8);
        assert!(pm.log.iter().any(|(n, c)| n == "decompose-llm" && *c));
        assert!(g.contains_op("llm.prefill"));
        assert!(g.contains_op("llm.decode"));
        assert!(g.contains_op("tool.lookup"));
        assert!(!g.contains_op("llm.infer"));
        assert!(!g.contains_op("tool.call"));
    }
}
