//! Decomposition passes (paper §4.2, Figure 7 b→c).
//!
//! "the LLM call is split into prefill and decode, and each tool
//! invocation is separated into a lookup and a compute stage. This
//! transformation reveals internal parallelism and resource
//! requirements, enabling the compiler to reason about scheduling,
//! placement, and pipelining across a heterogeneous system."

use std::collections::BTreeMap;

use super::{for_each_region, Pass};
use crate::ir::graph::{Graph, Node, NodeId};
use crate::Result;

/// `llm.infer(x)` → `llm.prefill(x)` + `kv.transfer(kv)` + `llm.decode`.
pub struct DecomposeLlm;

impl Pass for DecomposeLlm {
    fn name(&self) -> &'static str {
        "decompose-llm"
    }

    fn run(&self, g: &mut Graph) -> Result<bool> {
        for_each_region(g, &mut |g| {
            let mut changed = false;
            let mut out: Vec<Node> = Vec::with_capacity(g.nodes.len());
            let nodes = std::mem::take(&mut g.nodes);
            for node in nodes {
                if node.op != "llm.infer" {
                    out.push(node);
                    continue;
                }
                changed = true;
                let old_result = node.results[0];

                // %h, %kv = llm.prefill(operands...)
                let h = g.fresh_value();
                let kv = g.fresh_value();
                let mut prefill_attrs = node.attrs.clone();
                prefill_attrs.insert("stage".into(), "prefill".into());
                out.push(Node {
                    id: NodeId(0),
                    op: "llm.prefill".into(),
                    operands: node.operands.clone(),
                    results: vec![h, kv],
                    attrs: prefill_attrs,
                    region: None,
                });

                // %kvr = kv.transfer(%kv)  — the disaggregation boundary;
                // the planner prices this edge (worked example's
                // "KV Transfer (HP -> CO)" row).
                let kvr = g.fresh_value();
                let mut t_attrs = BTreeMap::new();
                if let Some(m) = node.attrs.get("model") {
                    t_attrs.insert("model".into(), m.clone());
                }
                if let Some(isl) = node.attrs.get("isl") {
                    t_attrs.insert("isl".into(), isl.clone());
                }
                out.push(Node {
                    id: NodeId(0),
                    op: "kv.transfer".into(),
                    operands: vec![kv],
                    results: vec![kvr],
                    attrs: t_attrs,
                    region: None,
                });

                // %out = llm.decode(%h, %kvr)
                let mut decode_attrs = node.attrs.clone();
                decode_attrs.insert("stage".into(), "decode".into());
                out.push(Node {
                    id: NodeId(0),
                    op: "llm.decode".into(),
                    operands: vec![h, kvr],
                    results: vec![old_result],
                    attrs: decode_attrs,
                    region: None,
                });
            }
            // Reassign node ids in order.
            g.nodes.clear();
            for n in out {
                g.push_node(n);
            }
            Ok(changed)
        })
    }
}

/// `tool.call(x)` → `tool.lookup(x)` + `tool.compute(lookup)`.
pub struct DecomposeTool;

impl Pass for DecomposeTool {
    fn name(&self) -> &'static str {
        "decompose-tool"
    }

    fn run(&self, g: &mut Graph) -> Result<bool> {
        for_each_region(g, &mut |g| {
            let mut changed = false;
            let nodes = std::mem::take(&mut g.nodes);
            let mut out = Vec::with_capacity(nodes.len());
            for node in nodes {
                if node.op != "tool.call" {
                    out.push(node);
                    continue;
                }
                changed = true;
                let old_result = node.results[0];
                let looked = g.fresh_value();
                let mut lk_attrs = node.attrs.clone();
                lk_attrs.insert("stage".into(), "lookup".into());
                out.push(Node {
                    id: NodeId(0),
                    op: "tool.lookup".into(),
                    operands: node.operands.clone(),
                    results: vec![looked],
                    attrs: lk_attrs,
                    region: None,
                });
                let mut cp_attrs = node.attrs.clone();
                cp_attrs.insert("stage".into(), "compute".into());
                out.push(Node {
                    id: NodeId(0),
                    op: "tool.compute".into(),
                    operands: vec![looked],
                    results: vec![old_result],
                    attrs: cp_attrs,
                    region: None,
                });
            }
            g.nodes.clear();
            for n in out {
                g.push_node(n);
            }
            Ok(changed)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse;
    use crate::ir::verifier::verify;

    #[test]
    fn llm_decomposition_preserves_uses() {
        let mut g = parse(
            r#"
graph @g() {
  %0 = io.input()
  %1 = llm.infer(%0) {model = "8b-fp16"}
  io.output(%1)
  yield %1
}
"#,
        )
        .unwrap();
        assert!(DecomposeLlm.run(&mut g).unwrap());
        verify(&g).unwrap();
        let ops = g.op_names();
        assert_eq!(
            ops,
            vec!["io.input", "llm.prefill", "kv.transfer", "llm.decode", "io.output"]
        );
        // io.output still consumes the (re-used) original value.
        let out_node = g.nodes.iter().find(|n| n.op == "io.output").unwrap();
        let decode = g.nodes.iter().find(|n| n.op == "llm.decode").unwrap();
        assert_eq!(out_node.operands[0], decode.results[0]);
        // Stage attrs attached, model propagated.
        let prefill = g.nodes.iter().find(|n| n.op == "llm.prefill").unwrap();
        assert_eq!(prefill.attr_str("stage"), Some("prefill"));
        assert_eq!(prefill.attr_str("model"), Some("8b-fp16"));
    }

    #[test]
    fn idempotent_when_no_llm() {
        let mut g = parse("graph @g() {\n %0 = io.input()\n yield %0\n}").unwrap();
        assert!(!DecomposeLlm.run(&mut g).unwrap());
    }

    #[test]
    fn tool_decomposition() {
        let mut g = parse(
            r#"
graph @g() {
  %0 = io.input()
  %1 = tool.call(%0) {tool = "calculator"}
  io.output(%1)
}
"#,
        )
        .unwrap();
        assert!(DecomposeTool.run(&mut g).unwrap());
        verify(&g).unwrap();
        assert!(g.contains_op("tool.lookup"));
        assert!(g.contains_op("tool.compute"));
        assert!(!g.contains_op("tool.call"));
        let lk = g.nodes.iter().find(|n| n.op == "tool.lookup").unwrap();
        assert_eq!(lk.attr_str("tool"), Some("calculator"));
    }

    #[test]
    fn decomposes_inside_regions() {
        let mut g = parse(
            r#"
graph @g() {
  %0 = io.input()
  %1 = ctrl.loop(%0) {max_trips = 2} {
    %0 = io.input()
    %1 = llm.infer(%0) {model = "8b-fp16"}
    yield %1
  }
  io.output(%1)
}
"#,
        )
        .unwrap();
        assert!(DecomposeLlm.run(&mut g).unwrap());
        verify(&g).unwrap();
        let region = g.nodes[1].region.as_ref().unwrap();
        assert!(region.contains_op("llm.prefill"));
    }

    #[test]
    fn multiple_llms_all_decomposed() {
        let mut g = parse(
            r#"
graph @g() {
  %0 = io.input()
  %1 = llm.infer(%0) {model = "8b-fp16"}
  %2 = llm.infer(%1) {model = "70b-fp8"}
  io.output(%2)
}
"#,
        )
        .unwrap();
        DecomposeLlm.run(&mut g).unwrap();
        verify(&g).unwrap();
        assert_eq!(g.op_names().iter().filter(|o| *o == "llm.prefill").count(), 2);
        assert_eq!(g.op_names().iter().filter(|o| *o == "kv.transfer").count(), 2);
    }
}
