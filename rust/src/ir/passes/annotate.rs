//! Cost annotation: "These passes enable extraction of resource usage
//! vectors θ_ij and latency terms t_ij, which feed directly into the
//! convex optimization framework and scheduler" (§4.2).
//!
//! Attaches to every node with a known workload class:
//! * `wl_class` — the Figure-3 class name;
//! * `demand_*` — the six-dimensional radar vector;
//! * `wants_accel` — accelerator vs CPU placement hint (§5: non-LLM
//!   voice-agent components go to CPUs);
//! * for `llm.prefill` / `llm.decode` with a resolvable `model` attr:
//!   `est_flops` and `est_bytes` from the analytic profile, using the
//!   node's `isl` / `osl` attrs (defaults 512 / 128).

use super::{for_each_region, Pass};
use crate::cost::model_profile::by_short_name;
use crate::cost::{Resource, ResourceVec};
use crate::ir::graph::Graph;
use crate::Result;

pub struct AnnotateCost {
    pub default_isl: u64,
    pub default_osl: u64,
}

impl Default for AnnotateCost {
    fn default() -> Self {
        AnnotateCost {
            default_isl: 512,
            default_osl: 128,
        }
    }
}

impl Pass for AnnotateCost {
    fn name(&self) -> &'static str {
        "annotate-cost"
    }

    fn run(&self, g: &mut Graph) -> Result<bool> {
        let (disl, dosl) = (self.default_isl, self.default_osl);
        for_each_region(g, &mut |g| {
            let mut changed = false;
            for n in &mut g.nodes {
                let Some(info) = crate::ir::ops::op(&n.op) else {
                    continue;
                };
                let Some(wl) = info.workload else { continue };
                changed = true;
                n.set_attr("wl_class", wl.name());
                n.set_attr("wants_accel", wl.wants_accelerator());
                let radar: ResourceVec = wl.radar();
                for r in Resource::ALL {
                    n.set_attr(&format!("demand_{}", r.name()), radar.get(r));
                }

                // Analytic FLOP/byte estimates for disaggregated stages.
                if n.op == "llm.prefill" || n.op == "llm.decode" || n.op == "kv.transfer" {
                    if let Some(model) =
                        n.attr_str("model").and_then(by_short_name)
                    {
                        let isl = n.attr_int("isl").map(|v| v as u64).unwrap_or(disl);
                        let osl = n.attr_int("osl").map(|v| v as u64).unwrap_or(dosl);
                        match n.op.as_str() {
                            "llm.prefill" => {
                                n.set_attr("est_flops", model.prefill_flops(isl));
                                n.set_attr("est_bytes", model.prefill_bytes(isl, 1));
                            }
                            "llm.decode" => {
                                let ctx = isl + osl / 2;
                                n.set_attr(
                                    "est_flops",
                                    model.decode_flops(ctx) * osl as f64,
                                );
                                n.set_attr(
                                    "est_bytes",
                                    model.decode_bytes(ctx, 1) * osl as f64,
                                );
                            }
                            "kv.transfer" => {
                                n.set_attr(
                                    "est_bytes",
                                    crate::cost::kv::kv_cache_bytes(&model, isl, 1),
                                );
                            }
                            _ => {}
                        }
                    }
                }
            }
            Ok(changed)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::parser::parse;
    use crate::ir::passes::decompose::DecomposeLlm;
    use crate::ir::verifier::verify;

    #[test]
    fn annotates_radar_and_estimates() {
        let mut g = parse(
            r#"
graph @g() {
  %0 = io.input()
  %1 = llm.infer(%0) {model = "8b-fp16", isl = 1024, osl = 256}
  %2 = tool.call(%1) {tool = "search"}
  io.output(%2)
}
"#,
        )
        .unwrap();
        DecomposeLlm.run(&mut g).unwrap();
        assert!(AnnotateCost::default().run(&mut g).unwrap());
        verify(&g).unwrap();

        let prefill = g.nodes.iter().find(|n| n.op == "llm.prefill").unwrap();
        assert_eq!(
            prefill.attr_str("wl_class"),
            Some("LLM Prefill (Disaggregated)")
        );
        assert_eq!(prefill.attr("wants_accel").unwrap().as_bool(), Some(true));
        assert!(prefill.attr_f64("demand_hp_compute").unwrap() >= 9.0);
        // 2 * 8e9 * 1024 + attention term.
        let flops = prefill.attr_f64("est_flops").unwrap();
        assert!(flops > 1.6e13 && flops < 1.8e13, "{flops}");

        let decode = g.nodes.iter().find(|n| n.op == "llm.decode").unwrap();
        assert!(decode.attr_f64("est_bytes").unwrap() > 0.0);

        let transfer = g.nodes.iter().find(|n| n.op == "kv.transfer").unwrap();
        // Eq 3 at isl=1024: 1024 * 131072 bytes.
        assert_eq!(
            transfer.attr_f64("est_bytes"),
            Some(1024.0 * 131_072.0)
        );

        let tool = g.nodes.iter().find(|n| n.op == "tool.call").unwrap();
        assert_eq!(tool.attr("wants_accel").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn unresolvable_model_still_gets_radar() {
        let mut g = parse(
            r#"
graph @g() {
  %0 = io.input()
  %1, %2 = llm.prefill(%0) {model = "mystery-13b"}
  io.output(%1)
}
"#,
        )
        .unwrap();
        AnnotateCost::default().run(&mut g).unwrap();
        let p = &g.nodes[1];
        assert!(p.attr_str("wl_class").is_some());
        assert!(p.attr("est_flops").is_none());
    }
}
