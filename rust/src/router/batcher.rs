//! Continuous batcher.
//!
//! Aggregates admitted requests into *bucketed* prefill batches — the
//! AOT artifact set is compiled at fixed batch sizes (see
//! `python/compile/aot.py`), so the batcher picks the largest bucket it
//! can fill (or the smallest that covers the waiting set once the batch
//! timeout expires) and pads the remainder. Decode-side it maintains a
//! rolling active set with join-at-round-boundary semantics (Orca-style
//! continuous batching, which the paper's framework "automatically
//! incorporates").

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// A queued prefill candidate.
#[derive(Debug, Clone, PartialEq)]
pub struct Pending<T> {
    pub payload: T,
    pub enqueued: Instant,
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Available batch buckets, ascending (must match the artifacts).
    pub buckets: Vec<usize>,
    /// Max time the head-of-line request may wait before a partial
    /// batch is released.
    pub max_wait: Duration,
    /// Decode round active-set cap.
    pub max_decode_batch: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            buckets: vec![1, 2, 4],
            max_wait: Duration::from_millis(10),
            max_decode_batch: 4,
        }
    }
}

/// A released prefill batch: the chosen bucket and the actual members
/// (members.len() <= bucket; the engine pads the rest).
#[derive(Debug, Clone)]
pub struct PrefillBatch<T> {
    pub bucket: usize,
    pub members: Vec<T>,
}

/// The continuous batcher.
#[derive(Debug)]
pub struct Batcher<T> {
    cfg: BatcherConfig,
    queue: VecDeque<Pending<T>>,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Batcher<T> {
        assert!(!cfg.buckets.is_empty(), "need at least one bucket");
        let mut cfg = cfg;
        cfg.buckets.sort_unstable();
        Batcher {
            cfg,
            queue: VecDeque::new(),
        }
    }

    pub fn push(&mut self, payload: T) {
        self.queue.push_back(Pending {
            payload,
            enqueued: Instant::now(),
        });
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Age of the head-of-line request.
    pub fn head_wait(&self, now: Instant) -> Duration {
        self.queue
            .front()
            .map(|p| now.duration_since(p.enqueued))
            .unwrap_or(Duration::ZERO)
    }

    /// The largest bucket, if the queue can fill it completely. Smaller
    /// buckets are only used on the timeout path — releasing them
    /// eagerly would defeat aggregation (a bucket-1 batch would always
    /// be "full").
    fn full_bucket(&self) -> Option<usize> {
        let largest = *self.cfg.buckets.last().unwrap();
        (self.queue.len() >= largest).then_some(largest)
    }

    /// Smallest bucket covering the whole queue (timeout path).
    fn covering_bucket(&self) -> usize {
        let n = self.queue.len();
        self.cfg
            .buckets
            .iter()
            .find(|b| **b >= n)
            .copied()
            .unwrap_or(*self.cfg.buckets.last().unwrap())
    }

    /// Release a batch if policy allows: a full largest bucket
    /// immediately, or whatever is queued once the head request has
    /// waited `max_wait`.
    pub fn poll(&mut self, now: Instant) -> Option<PrefillBatch<T>> {
        if self.queue.is_empty() {
            return None;
        }
        if let Some(bucket) = self.full_bucket() {
            let members = self.take(bucket);
            return Some(PrefillBatch { bucket, members });
        }
        if self.head_wait(now) >= self.cfg.max_wait {
            let bucket = self.covering_bucket();
            let members = self.take(self.queue.len().min(bucket));
            return Some(PrefillBatch { bucket, members });
        }
        None
    }

    /// Force-release everything (shutdown / drain).
    pub fn drain(&mut self) -> Vec<PrefillBatch<T>> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            let bucket = self.covering_bucket();
            let members = self.take(self.queue.len().min(bucket));
            out.push(PrefillBatch { bucket, members });
        }
        out
    }

    fn take(&mut self, n: usize) -> Vec<T> {
        self.queue.drain(..n).map(|p| p.payload).collect()
    }

    pub fn config(&self) -> &BatcherConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(ms: u64) -> BatcherConfig {
        BatcherConfig {
            buckets: vec![1, 2, 4],
            max_wait: Duration::from_millis(ms),
            max_decode_batch: 4,
        }
    }

    #[test]
    fn full_bucket_released_immediately() {
        let mut b = Batcher::new(cfg(1000));
        for i in 0..5 {
            b.push(i);
        }
        let batch = b.poll(Instant::now()).unwrap();
        assert_eq!(batch.bucket, 4);
        assert_eq!(batch.members, vec![0, 1, 2, 3]);
        assert_eq!(b.len(), 1);
        // Remaining single request is not released before the timeout.
        assert!(b.poll(Instant::now()).is_none());
    }

    #[test]
    fn timeout_releases_partial_batch() {
        let mut b = Batcher::new(cfg(0)); // immediate timeout
        b.push(42);
        let batch = b.poll(Instant::now()).unwrap();
        assert_eq!(batch.bucket, 1);
        assert_eq!(batch.members, vec![42]);
    }

    #[test]
    fn covering_bucket_pads_three_to_four() {
        let mut b = Batcher::new(cfg(0));
        for i in 0..3 {
            b.push(i);
        }
        // 3 < 4: not a full largest bucket; the (immediate) timeout path
        // picks the smallest covering bucket — 4 — and pads 3 into it.
        let batch = b.poll(Instant::now()).unwrap();
        assert_eq!(batch.bucket, 4);
        assert_eq!(batch.members.len(), 3);
        assert!(b.is_empty());
    }

    #[test]
    fn fifo_order_preserved() {
        let mut b = Batcher::new(cfg(1000));
        for i in 0..4 {
            b.push(i);
        }
        let batch = b.poll(Instant::now()).unwrap();
        assert_eq!(batch.members, vec![0, 1, 2, 3]);
    }

    #[test]
    fn drain_flushes_all() {
        let mut b = Batcher::new(cfg(60_000));
        for i in 0..7 {
            b.push(i);
        }
        let batches = b.drain();
        assert!(b.is_empty());
        let total: usize = batches.iter().map(|x| x.members.len()).sum();
        assert_eq!(total, 7);
        // All batches respect bucket sizes.
        for batch in &batches {
            assert!(batch.members.len() <= batch.bucket);
            assert!([1, 2, 4].contains(&batch.bucket));
        }
    }

    #[test]
    fn empty_poll_none() {
        let mut b: Batcher<u32> = Batcher::new(cfg(0));
        assert!(b.poll(Instant::now()).is_none());
        assert_eq!(b.head_wait(Instant::now()), Duration::ZERO);
    }

    #[test]
    #[should_panic(expected = "at least one bucket")]
    fn empty_buckets_panics() {
        let _ = Batcher::<u32>::new(BatcherConfig {
            buckets: vec![],
            max_wait: Duration::ZERO,
            max_decode_batch: 1,
        });
    }
}
