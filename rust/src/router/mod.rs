//! Fast-path request routing (paper §4.1 "Load Balancer / Request
//! Router": "routes requests based on cache locality and model
//! availability, optimizing resource utilization and request
//! aggregation for performance").
//!
//! * [`router`] — per-request routing decisions: cache-locality first,
//!   then least-outstanding-load, with model-availability filtering;
//! * [`batcher`] — the continuous batcher that aggregates admitted
//!   requests into bucketed prefill batches and rolling decode rounds
//!   (bucket sizes match the AOT artifact set);
//! * [`admission`] — token-bucket admission control and queue-depth
//!   backpressure.

pub mod admission;
pub mod batcher;
pub mod router;

pub use admission::AdmissionController;
pub use batcher::{Batcher, BatcherConfig};
pub use router::{Router, RouterConfig, WorkerState};
