//! Cache-locality-aware load balancing.
//!
//! Decision order (richest information first):
//! 1. a worker already holding this *session's* KV (multi-turn hit);
//! 2. a worker holding a matching *prefix* cache (shared system prompt);
//! 3. the least-loaded worker that serves the requested model.
//!
//! Workers whose queue depth exceeds `max_queue` are skipped (the
//! admission controller should have shed these, but the router defends
//! independently).

use std::collections::BTreeMap;

use crate::kvcache::manager::CacheManager;
use crate::{Error, Result};

/// Router view of one worker (decode/prefill engine instance).
#[derive(Debug, Clone)]
pub struct WorkerState {
    pub id: u32,
    /// Models this worker has loaded (artifact names).
    pub models: Vec<String>,
    /// Outstanding requests.
    pub outstanding: u32,
    /// Draining workers accept no new work (planner migration).
    pub draining: bool,
}

#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Queue depth beyond which a worker is skipped.
    pub max_queue: u32,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { max_queue: 256 }
    }
}

/// The decision the router made (for metrics/tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteReason {
    SessionAffinity,
    PrefixHit,
    LeastLoaded,
}

/// The fast-path router.
#[derive(Debug)]
pub struct Router {
    cfg: RouterConfig,
    workers: BTreeMap<u32, WorkerState>,
}

impl Router {
    pub fn new(cfg: RouterConfig) -> Router {
        Router {
            cfg,
            workers: BTreeMap::new(),
        }
    }

    pub fn upsert_worker(&mut self, w: WorkerState) {
        self.workers.insert(w.id, w);
    }

    pub fn remove_worker(&mut self, id: u32) {
        self.workers.remove(&id);
    }

    pub fn set_draining(&mut self, id: u32, draining: bool) {
        if let Some(w) = self.workers.get_mut(&id) {
            w.draining = draining;
        }
    }

    pub fn note_dispatch(&mut self, id: u32) {
        if let Some(w) = self.workers.get_mut(&id) {
            w.outstanding += 1;
        }
    }

    pub fn note_complete(&mut self, id: u32) {
        if let Some(w) = self.workers.get_mut(&id) {
            w.outstanding = w.outstanding.saturating_sub(1);
        }
    }

    pub fn worker(&self, id: u32) -> Option<&WorkerState> {
        self.workers.get(&id)
    }

    fn eligible(&self, w: &WorkerState, model: &str) -> bool {
        !w.draining
            && w.outstanding < self.cfg.max_queue
            && w.models.iter().any(|m| m == model)
    }

    /// Route a request; returns (worker id, reason).
    pub fn route(
        &self,
        model: &str,
        session: Option<u64>,
        prefix_hash: Option<u64>,
        cache: &CacheManager,
    ) -> Result<(u32, RouteReason)> {
        // 1. Session affinity.
        if let Some(sid) = session {
            if let Some((node, _tier)) = cache.locate(sid) {
                if let Some(w) = self.workers.get(&node) {
                    if self.eligible(w, model) {
                        return Ok((node, RouteReason::SessionAffinity));
                    }
                }
            }
        }
        // 2. Prefix-cache hit.
        if let Some(ph) = prefix_hash {
            if let Some(node) = cache.find_prefix(ph) {
                if let Some(w) = self.workers.get(&node) {
                    if self.eligible(w, model) {
                        return Ok((node, RouteReason::PrefixHit));
                    }
                }
            }
        }
        // 3. Least outstanding load.
        self.workers
            .values()
            .filter(|w| self.eligible(w, model))
            .min_by_key(|w| (w.outstanding, w.id))
            .map(|w| (w.id, RouteReason::LeastLoaded))
            .ok_or_else(|| {
                Error::Capacity(format!("no eligible worker for model {model}"))
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::manager::{CacheManager, NodeBudget};
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn cache(nodes: usize) -> CacheManager {
        CacheManager::new(
            (0..nodes)
                .map(|_| NodeBudget {
                    hbm: 1e9,
                    dram: 4e9,
                    disk: 1e12,
                })
                .collect(),
        )
    }

    fn worker(id: u32, outstanding: u32) -> WorkerState {
        WorkerState {
            id,
            models: vec!["tiny".into()],
            outstanding,
            draining: false,
        }
    }

    fn router3() -> Router {
        let mut r = Router::new(RouterConfig::default());
        r.upsert_worker(worker(0, 5));
        r.upsert_worker(worker(1, 2));
        r.upsert_worker(worker(2, 9));
        r
    }

    #[test]
    fn least_loaded_wins_without_cache() {
        let r = router3();
        let c = cache(3);
        let (id, why) = r.route("tiny", None, None, &c).unwrap();
        assert_eq!(id, 1);
        assert_eq!(why, RouteReason::LeastLoaded);
    }

    #[test]
    fn session_affinity_beats_load() {
        let r = router3();
        let mut c = cache(3);
        c.insert(77, 2, 100.0, 0xAA).unwrap(); // session 77 on busy worker 2
        let (id, why) = r.route("tiny", Some(77), None, &c).unwrap();
        assert_eq!(id, 2);
        assert_eq!(why, RouteReason::SessionAffinity);
    }

    #[test]
    fn prefix_hit_beats_load() {
        let r = router3();
        let mut c = cache(3);
        c.insert(1, 0, 10.0, 0xFEED).unwrap();
        let (id, why) = r.route("tiny", None, Some(0xFEED), &c).unwrap();
        assert_eq!(id, 0);
        assert_eq!(why, RouteReason::PrefixHit);
    }

    #[test]
    fn draining_worker_skipped_even_with_affinity() {
        let mut r = router3();
        let mut c = cache(3);
        c.insert(77, 2, 100.0, 0).unwrap();
        r.set_draining(2, true);
        let (id, why) = r.route("tiny", Some(77), None, &c).unwrap();
        assert_ne!(id, 2);
        assert_eq!(why, RouteReason::LeastLoaded);
    }

    #[test]
    fn model_availability_filters() {
        let mut r = router3();
        r.upsert_worker(WorkerState {
            id: 3,
            models: vec!["big".into()],
            outstanding: 0,
            draining: false,
        });
        let c = cache(4);
        let (id, _) = r.route("big", None, None, &c).unwrap();
        assert_eq!(id, 3);
        assert!(r.route("unknown-model", None, None, &c).is_err());
    }

    #[test]
    fn full_queue_skipped() {
        let mut r = Router::new(RouterConfig { max_queue: 4 });
        r.upsert_worker(worker(0, 4)); // at limit
        r.upsert_worker(worker(1, 3));
        let c = cache(2);
        let (id, _) = r.route("tiny", None, None, &c).unwrap();
        assert_eq!(id, 1);
        r.note_dispatch(1);
        assert!(r.route("tiny", None, None, &c).is_err());
    }

    #[test]
    fn dispatch_complete_bookkeeping() {
        let mut r = router3();
        r.note_dispatch(1);
        r.note_dispatch(1);
        assert_eq!(r.worker(1).unwrap().outstanding, 4);
        r.note_complete(1);
        assert_eq!(r.worker(1).unwrap().outstanding, 3);
        // Underflow-safe.
        let mut r2 = Router::new(RouterConfig::default());
        r2.upsert_worker(worker(9, 0));
        r2.note_complete(9);
        assert_eq!(r2.worker(9).unwrap().outstanding, 0);
    }

    #[test]
    fn balance_property_spreads_load() {
        // Routing n requests (completing none) never leaves the gap
        // between max and min outstanding above 1 when all workers are
        // identical — the invariant of least-loaded balancing.
        prop::check("router-balances", |rng: &mut Rng| {
            let k = rng.index(4) + 2;
            let mut r = Router::new(RouterConfig { max_queue: 10_000 });
            for id in 0..k {
                r.upsert_worker(worker(id as u32, 0));
            }
            let c = cache(k);
            for _ in 0..rng.index(100) {
                let (id, _) = r.route("tiny", None, None, &c).unwrap();
                r.note_dispatch(id);
            }
            let outs: Vec<u32> = (0..k)
                .map(|i| r.worker(i as u32).unwrap().outstanding)
                .collect();
            let max = *outs.iter().max().unwrap();
            let min = *outs.iter().min().unwrap();
            assert!(max - min <= 1, "unbalanced: {outs:?}");
        });
    }
}
