//! Admission control: token-bucket rate limiting plus queue-depth
//! backpressure (§4.1's orchestration "helps prevent resource
//! contention"). Requests rejected here never consume accelerator time.

use std::time::Instant;

/// Decision for one arriving request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    Accept,
    /// Over rate limit; client should retry after backoff.
    Throttled,
    /// System queue too deep; shed load.
    Shed,
}

#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Sustained requests/second.
    pub rate: f64,
    /// Burst capacity (token bucket depth).
    pub burst: f64,
    /// Queue depth at which load is shed outright.
    pub max_queue_depth: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            rate: 1000.0,
            burst: 100.0,
            max_queue_depth: 4096,
        }
    }
}

/// Token-bucket admission controller.
#[derive(Debug)]
pub struct AdmissionController {
    cfg: AdmissionConfig,
    tokens: f64,
    last: Instant,
    pub accepted: u64,
    pub throttled: u64,
    pub shed: u64,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> AdmissionController {
        AdmissionController {
            tokens: cfg.burst,
            cfg,
            last: Instant::now(),
            accepted: 0,
            throttled: 0,
            shed: 0,
        }
    }

    fn refill(&mut self, now: Instant) {
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        self.tokens = (self.tokens + dt * self.cfg.rate).min(self.cfg.burst);
    }

    /// Decide for one request given current system queue depth.
    pub fn admit(&mut self, now: Instant, queue_depth: usize) -> Admission {
        self.refill(now);
        if queue_depth >= self.cfg.max_queue_depth {
            self.shed += 1;
            return Admission::Shed;
        }
        if self.tokens < 1.0 {
            self.throttled += 1;
            return Admission::Throttled;
        }
        self.tokens -= 1.0;
        self.accepted += 1;
        Admission::Accept
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn ctl(rate: f64, burst: f64, depth: usize) -> AdmissionController {
        AdmissionController::new(AdmissionConfig {
            rate,
            burst,
            max_queue_depth: depth,
        })
    }

    #[test]
    fn burst_accepted_then_throttled() {
        let mut c = ctl(10.0, 5.0, 100);
        let now = Instant::now();
        let mut accepted = 0;
        for _ in 0..10 {
            if c.admit(now, 0) == Admission::Accept {
                accepted += 1;
            }
        }
        assert_eq!(accepted, 5);
        assert_eq!(c.throttled, 5);
    }

    #[test]
    fn refill_restores_admission() {
        let mut c = ctl(1000.0, 2.0, 100);
        let t0 = Instant::now();
        assert_eq!(c.admit(t0, 0), Admission::Accept);
        assert_eq!(c.admit(t0, 0), Admission::Accept);
        assert_eq!(c.admit(t0, 0), Admission::Throttled);
        // 10 ms later the bucket has refilled (1000/s × 0.01 = 10 > 2).
        let t1 = t0 + Duration::from_millis(10);
        assert_eq!(c.admit(t1, 0), Admission::Accept);
    }

    #[test]
    fn deep_queue_sheds_regardless_of_tokens() {
        let mut c = ctl(1000.0, 100.0, 8);
        assert_eq!(c.admit(Instant::now(), 8), Admission::Shed);
        assert_eq!(c.shed, 1);
    }

    #[test]
    fn counters_accumulate() {
        let mut c = ctl(10.0, 1.0, 2);
        let now = Instant::now();
        c.admit(now, 0); // accept
        c.admit(now, 0); // throttle
        c.admit(now, 5); // shed
        assert_eq!((c.accepted, c.throttled, c.shed), (1, 1, 1));
    }
}
