//! Critical-path SLA attribution over [`Span`] trees.
//!
//! For each request, walk the span DAG backwards from the
//! last-finishing execution span along `parent` edges (each span names
//! the dependency that gated it — the last-arriving input), and charge
//! every second of end-to-end latency to one of six buckets:
//!
//! `queue` · `prefill` · `decode` · `kv_transfer` · `host` · `tool_io`
//!
//! Execution time goes to the span's kind, recorded queue waits and any
//! *unspanned* residual gaps on the critical path go to `queue`, so the
//! buckets always sum to the request's e2e latency exactly. The
//! `coverage` figure reports how much of that total was **explicitly
//! measured** (execution + transfers + recorded waits) rather than
//! inferred residual — the honest number behind "attribution sums to
//! ≥95% of e2e".
//!
//! Aggregation is per window ([`attribute_windows`] aligns to the
//! orchestrator's observation windows by request completion time) and
//! per pipeline group, which is what turns a trace into the
//! measured-work signal the `GroupScaler` wants: "what fraction of p95
//! was fabric contention on the old-generation chassis" is a lookup in
//! [`SlaAttribution::by_group`].

use std::collections::BTreeMap;

use super::trace::{Span, SpanKind};
use crate::util::json::Json;
use crate::{Error, Result};

/// The six attribution buckets, in reporting order.
pub const BUCKETS: [&str; 6] = [
    "queue",
    "prefill",
    "decode",
    "kv_transfer",
    "host",
    "tool_io",
];

fn bucket_of(kind: SpanKind) -> &'static str {
    match kind {
        SpanKind::Prefill => "prefill",
        SpanKind::Decode => "decode",
        SpanKind::KvTransfer => "kv_transfer",
        SpanKind::Host => "host",
        SpanKind::ToolIo => "tool_io",
        SpanKind::Request => "queue", // envelope time itself is never charged here
    }
}

/// Latency attribution aggregated over one window of completed
/// requests.
#[derive(Debug, Clone, PartialEq)]
pub struct SlaAttribution {
    /// Window bounds (modeled seconds); requests are assigned by
    /// completion time.
    pub t0: f64,
    pub t1: f64,
    /// Completed requests attributed in this window.
    pub requests: u64,
    /// Sum of per-request e2e latencies (== sum over all buckets).
    pub e2e_total_s: f64,
    /// Fraction of `e2e_total_s` that was explicitly measured (span
    /// execution + transfers + recorded queue waits) rather than
    /// residual gap.
    pub coverage: f64,
    /// Worst per-request explicit coverage in the window.
    pub min_request_coverage: f64,
    /// Seconds per bucket, summed over requests.
    pub by_bucket: BTreeMap<String, f64>,
    /// Seconds per bucket per pipeline group (`"host"` for host-pool
    /// stages).
    pub by_group: BTreeMap<String, BTreeMap<String, f64>>,
}

impl SlaAttribution {
    fn empty(t0: f64, t1: f64) -> SlaAttribution {
        SlaAttribution {
            t0,
            t1,
            requests: 0,
            e2e_total_s: 0.0,
            coverage: 1.0,
            min_request_coverage: 1.0,
            by_bucket: BTreeMap::new(),
            by_group: BTreeMap::new(),
        }
    }

    pub fn to_json(&self) -> Json {
        let bucket_obj = |m: &BTreeMap<String, f64>| {
            let mut o = Json::obj();
            for (k, v) in m {
                let _ = o.try_set(k, *v);
            }
            o
        };
        let mut groups = Json::obj();
        for (g, m) in &self.by_group {
            let _ = groups.try_set(g, bucket_obj(m));
        }
        crate::jobj! {
            "t0" => self.t0,
            "t1" => self.t1,
            "requests" => self.requests,
            "e2e_total_s" => self.e2e_total_s,
            "coverage" => self.coverage,
            "min_request_coverage" => self.min_request_coverage,
            "by_bucket" => bucket_obj(&self.by_bucket),
            "by_group" => groups,
        }
    }

    pub fn from_json(j: &Json) -> Result<SlaAttribution> {
        let f = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| Error::Runtime(format!("attribution missing `{k}`")))
        };
        let buckets_of = |v: &Json| -> BTreeMap<String, f64> {
            match v {
                Json::Obj(m) => m
                    .iter()
                    .filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x)))
                    .collect(),
                _ => BTreeMap::new(),
            }
        };
        let mut by_group = BTreeMap::new();
        if let Some(Json::Obj(m)) = j.get("by_group") {
            for (g, v) in m {
                by_group.insert(g.clone(), buckets_of(v));
            }
        }
        Ok(SlaAttribution {
            t0: f("t0")?,
            t1: f("t1")?,
            requests: f("requests")? as u64,
            e2e_total_s: f("e2e_total_s")?,
            coverage: f("coverage")?,
            min_request_coverage: f("min_request_coverage")?,
            by_bucket: j.get("by_bucket").map(buckets_of).unwrap_or_default(),
            by_group,
        })
    }

    /// Seconds charged to `bucket` (0 when absent).
    pub fn bucket_s(&self, bucket: &str) -> f64 {
        self.by_bucket.get(bucket).copied().unwrap_or(0.0)
    }

    /// Render the aggregate attribution table (the `trace-report`
    /// output): one row per group plus a totals row, with per-bucket
    /// seconds and the share of total e2e.
    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{} requests, e2e total {:.3}s, explicit coverage {:.1}% \
             (worst request {:.1}%)\n",
            self.requests,
            self.e2e_total_s,
            self.coverage * 100.0,
            self.min_request_coverage * 100.0
        ));
        out.push_str(&format!("{:<34}", "group"));
        for b in BUCKETS {
            out.push_str(&format!(" {b:>12}"));
        }
        out.push_str(&format!(" {:>12}\n", "total"));
        let mut row = |name: &str, m: &BTreeMap<String, f64>| {
            out.push_str(&format!("{name:<34}"));
            let mut total = 0.0;
            for b in BUCKETS {
                let v = m.get(b).copied().unwrap_or(0.0);
                total += v;
                out.push_str(&format!(" {:>11.3}s", v));
            }
            out.push_str(&format!(" {total:>11.3}s\n"));
        };
        for (g, m) in &self.by_group {
            let name = if g.is_empty() { "(admission)" } else { g.as_str() };
            row(name, m);
        }
        row("TOTAL", &self.by_bucket);
        if self.e2e_total_s > 0.0 {
            out.push_str(&format!("{:<34}", "share of e2e"));
            for b in BUCKETS {
                out.push_str(&format!(
                    " {:>11.1}%",
                    self.bucket_s(b) / self.e2e_total_s * 100.0
                ));
            }
            out.push('\n');
        }
        out
    }
}

/// One request's walked critical path.
struct RequestWalk {
    e2e: f64,
    explicit: f64,
    /// (group, bucket, seconds)
    contributions: Vec<(String, &'static str, f64)>,
}

/// Walk one request's spans. `spans` must all share the same request
/// id. Returns `None` when the request has no spans at all.
fn walk_request(spans: &[&Span]) -> Option<RequestWalk> {
    let envelope = spans.iter().find(|s| s.kind == SpanKind::Request);
    // Execution spans by node; KV transfers by (destination, source).
    let mut exec: BTreeMap<i64, &Span> = BTreeMap::new();
    let mut kv: BTreeMap<(i64, i64), &Span> = BTreeMap::new();
    for s in spans {
        match s.kind {
            SpanKind::Request => {}
            SpanKind::KvTransfer => {
                kv.insert((s.node, s.parent), s);
            }
            _ => {
                // Keep the latest-finishing span per node (decode
                // rounds fold into one span already, but be defensive).
                exec.entry(s.node)
                    .and_modify(|e| {
                        if s.t_end > e.t_end {
                            *e = s;
                        }
                    })
                    .or_insert(s);
            }
        }
    }
    let (r_start, r_end, admission) = match envelope {
        Some(e) => (e.t_start, e.t_end, e.queue_wait.max(0.0)),
        None => {
            let lo = spans
                .iter()
                .map(|s| s.t_start - s.queue_wait)
                .fold(f64::INFINITY, f64::min);
            let hi = spans.iter().map(|s| s.t_end).fold(0.0f64, f64::max);
            if !lo.is_finite() {
                return None;
            }
            (lo, hi, 0.0)
        }
    };
    let e2e = (r_end - r_start).max(0.0);
    let mut contributions: Vec<(String, &'static str, f64)> = Vec::new();
    let mut explicit = 0.0;

    let Some(last) = exec.values().max_by(|a, b| a.t_end.total_cmp(&b.t_end)) else {
        // No execution spans: the whole request is unexplained queue.
        contributions.push((String::new(), "queue", e2e));
        return Some(RequestWalk {
            e2e,
            explicit: 0.0,
            contributions,
        });
    };

    // Tail gap: completion bookkeeping after the last span.
    let tail = (r_end - last.t_end).max(0.0);
    if tail > 0.0 {
        contributions.push((last.group.clone(), "queue", tail));
    }

    let mut cur = *last;
    let mut visited: std::collections::BTreeSet<i64> = std::collections::BTreeSet::new();
    loop {
        if !visited.insert(cur.node) {
            break; // malformed parent cycle: stop, residual covers it
        }
        let dur = cur.duration_s();
        contributions.push((cur.group.clone(), bucket_of(cur.kind), dur));
        explicit += dur;
        let wait = cur.queue_wait.max(0.0);
        if wait > 0.0 {
            contributions.push((cur.group.clone(), "queue", wait));
            explicit += wait;
        }
        // When this span became ready/enqueued.
        let mut cursor = cur.t_start - wait;
        if cur.parent < 0 {
            // Root: admission wait, then any unexplained lead-in gap.
            if admission > 0.0 {
                contributions.push((String::new(), "queue", admission));
                explicit += admission;
            }
            let gap = (cursor - r_start - admission).max(0.0);
            if gap > 0.0 {
                contributions.push((cur.group.clone(), "queue", gap));
            }
            break;
        }
        // A fabric transfer may have delivered the gating input.
        if let Some(t) = kv.get(&(cur.node, cur.parent)) {
            let gap = (cursor - t.t_end).max(0.0);
            if gap > 0.0 {
                contributions.push((cur.group.clone(), "queue", gap));
            }
            let tdur = t.duration_s();
            contributions.push((t.group.clone(), "kv_transfer", tdur));
            explicit += tdur;
            cursor = t.t_start;
        }
        let Some(parent) = exec.get(&cur.parent) else {
            // Parent span missing (e.g. truncated trace): charge the
            // remaining lead-in to queue and stop.
            let gap = (cursor - r_start).max(0.0);
            if gap > 0.0 {
                contributions.push((cur.group.clone(), "queue", gap));
            }
            break;
        };
        let gap = (cursor - parent.t_end).max(0.0);
        if gap > 0.0 {
            contributions.push((cur.group.clone(), "queue", gap));
        }
        cur = parent;
    }

    // Normalize: float drift and overlapping parallel paths can make
    // the walked total differ slightly from e2e; scale the bucket sums
    // so they add to e2e exactly (the walk is a single chain, so this
    // is a no-op in the common case).
    let total: f64 = contributions.iter().map(|(_, _, s)| s).sum();
    if total > 0.0 && e2e > 0.0 && (total - e2e).abs() > 1e-9 {
        let scale = e2e / total;
        for c in &mut contributions {
            c.2 *= scale;
        }
        explicit *= scale;
    }
    Some(RequestWalk {
        e2e,
        explicit,
        contributions,
    })
}

/// Attribute every request whose completion lands in `[t0, t1)`.
pub fn attribute(spans: &[Span], t0: f64, t1: f64) -> SlaAttribution {
    let mut by_req: BTreeMap<u64, Vec<&Span>> = BTreeMap::new();
    for s in spans {
        by_req.entry(s.request).or_default().push(s);
    }
    let mut out = SlaAttribution::empty(t0, t1);
    let mut explicit_total = 0.0;
    for (_, req_spans) in by_req {
        let end = req_spans
            .iter()
            .find(|s| s.kind == SpanKind::Request)
            .map(|s| s.t_end)
            .unwrap_or_else(|| req_spans.iter().map(|s| s.t_end).fold(0.0f64, f64::max));
        if end < t0 || end >= t1 {
            continue;
        }
        let Some(walk) = walk_request(&req_spans) else {
            continue;
        };
        out.requests += 1;
        out.e2e_total_s += walk.e2e;
        explicit_total += walk.explicit;
        let req_cov = if walk.e2e > 0.0 {
            (walk.explicit / walk.e2e).min(1.0)
        } else {
            1.0
        };
        out.min_request_coverage = out.min_request_coverage.min(req_cov);
        for (group, bucket, secs) in walk.contributions {
            *out.by_bucket.entry(bucket.to_string()).or_insert(0.0) += secs;
            *out
                .by_group
                .entry(group)
                .or_default()
                .entry(bucket.to_string())
                .or_insert(0.0) += secs;
        }
    }
    out.coverage = if out.e2e_total_s > 0.0 {
        (explicit_total / out.e2e_total_s).min(1.0)
    } else {
        1.0
    };
    out
}

/// Attribute the whole trace as one window.
pub fn attribute_all(spans: &[Span]) -> SlaAttribution {
    attribute(spans, f64::NEG_INFINITY, f64::INFINITY)
}

/// Attribute per observation window (aligned with the autoscaler's
/// windows by request **completion** time, matching how
/// `WindowStats.completed` counts them).
pub fn attribute_windows(spans: &[Span], windows: &[(f64, f64)]) -> Vec<SlaAttribution> {
    windows
        .iter()
        .map(|&(t0, t1)| attribute(spans, t0, t1))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(
        request: u64,
        node: i64,
        kind: SpanKind,
        group: &str,
        t_start: f64,
        t_end: f64,
        parent: i64,
        queue_wait: f64,
    ) -> Span {
        Span {
            request,
            node,
            kind,
            group: group.into(),
            chassis: 0,
            t_start,
            t_end,
            parent,
            queue_wait,
        }
    }

    /// One request: admission 0.05, host 0.1 (root), queued 0.05 before
    /// prefill 0.2, kv hop 0.3, decode 0.25, tail 0.05.
    fn chain() -> Vec<Span> {
        vec![
            span(7, -1, SpanKind::Request, "", 0.0, 1.0, -1, 0.05),
            span(7, 0, SpanKind::Host, "host", 0.05, 0.15, -1, 0.0),
            span(7, 1, SpanKind::Prefill, "pre", 0.2, 0.4, 0, 0.05),
            span(7, 2, SpanKind::KvTransfer, "dec", 0.4, 0.7, 1, 0.0),
            span(7, 2, SpanKind::Decode, "dec", 0.7, 0.95, 1, 0.0),
        ]
    }

    #[test]
    fn buckets_sum_to_e2e_and_coverage_is_explicit() {
        let a = attribute_all(&chain());
        assert_eq!(a.requests, 1);
        assert!((a.e2e_total_s - 1.0).abs() < 1e-9);
        let sum: f64 = a.by_bucket.values().sum();
        assert!((sum - 1.0).abs() < 1e-9, "buckets must sum to e2e: {sum}");
        assert!((a.bucket_s("host") - 0.1).abs() < 1e-9);
        assert!((a.bucket_s("prefill") - 0.2).abs() < 1e-9);
        assert!((a.bucket_s("kv_transfer") - 0.3).abs() < 1e-9);
        assert!((a.bucket_s("decode") - 0.25).abs() < 1e-9);
        // queue = admission 0.05 + recorded wait 0.05 + tail 0.05 = 0.15
        assert!((a.bucket_s("queue") - 0.15).abs() < 1e-9);
        // Only the 0.05 tail gap is residual: coverage 95%.
        assert!((a.coverage - 0.95).abs() < 1e-9, "{}", a.coverage);
        assert!((a.min_request_coverage - 0.95).abs() < 1e-9);
        // Group split: the hop is charged to the decode group.
        assert!((a.by_group["dec"]["kv_transfer"] - 0.3).abs() < 1e-9);
        assert!((a.by_group["pre"]["prefill"] - 0.2).abs() < 1e-9);
    }

    #[test]
    fn windows_assign_by_completion_time() {
        let mut spans = chain();
        let mut late = chain();
        for s in &mut late {
            s.request = 8;
            s.t_start += 2.0;
            s.t_end += 2.0;
        }
        spans.extend(late);
        let ws = attribute_windows(&spans, &[(0.0, 2.0), (2.0, 4.0)]);
        assert_eq!(ws[0].requests, 1);
        assert_eq!(ws[1].requests, 1);
        assert!((ws[1].e2e_total_s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn attribution_json_round_trips() {
        let a = attribute_all(&chain());
        let j = a.to_json();
        let back = SlaAttribution::from_json(&j).unwrap();
        assert_eq!(back, a);
        // Byte-stable through the writer.
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap().to_string(), text);
    }

    #[test]
    fn table_mentions_groups_and_buckets() {
        let t = attribute_all(&chain()).table();
        assert!(t.contains("kv_transfer"), "{t}");
        assert!(t.contains("dec"), "{t}");
        assert!(t.contains("TOTAL"), "{t}");
        assert!(t.contains("share of e2e"), "{t}");
    }
}
