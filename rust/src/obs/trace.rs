//! Unified span tracing: one schema, two backends.
//!
//! A [`Span`] is one timed segment of one request — a host/tool stage,
//! an LLM prefill or decode, a cross-chassis KV transfer, or the
//! request envelope itself — stamped with the pipeline group and
//! chassis it ran on, the dependency edge that gated it (`parent`), and
//! how long it queued before starting (`queue_wait`). The live server
//! (`server/dag_exec.rs` + friends) records spans in **modeled
//! seconds** (wall time divided by the time scale), and the DAG
//! simulator (`cluster/dag.rs`) emits the *same schema* from its event
//! loop, so `obs/critical_path.rs` and the `trace-report` CLI analyze
//! either backend's output interchangeably — and a conformance test can
//! pin that the two span trees match structurally.
//!
//! The [`TraceSink`] is lock-light: recording takes one atomic
//! fetch-add plus a short push under one of a fixed set of shard
//! mutexes, so engine workers, host-pool workers, and the dispatcher
//! never serialize on a single lock. When tracing is disabled the sink
//! is simply absent (`Option<Arc<TraceSink>>`) and [`record_with`]
//! never runs its closure — the fast path allocates nothing.
//!
//! Export is Chrome trace-event JSON ([`to_chrome_json`]), viewable in
//! Perfetto / `chrome://tracing`: spans become `ph:"X"` complete
//! events (µs timestamps), pipeline groups become processes (named via
//! `ph:"M"` metadata events), and requests become threads. The full
//! span fields ride in `args`, so [`spans_from_chrome_json`] recovers
//! the exact `Vec<Span>` for offline attribution.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::util::json::Json;
use crate::{Error, Result};

/// What kind of work a span measures.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// The request envelope: submit → final completion. `queue_wait`
    /// holds the admission wait (0 in the simulator, which admits
    /// instantly at arrival).
    Request,
    /// Generic host CPU stage (STT, TTS, pre/post-processing).
    Host,
    /// Tool call or IO stage (`tool.*` / `io.*` ops) — split from
    /// `Host` because agent patterns exist where these dominate.
    ToolIo,
    /// LLM prefill execution on a prefill-group engine.
    Prefill,
    /// LLM decode execution (all rounds) on a decode-group engine.
    Decode,
    /// A cross-chassis transfer on the contended fabric (fused
    /// prefill→decode KV handoff or a DAG-edge payload).
    KvTransfer,
}

impl SpanKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            SpanKind::Request => "request",
            SpanKind::Host => "host",
            SpanKind::ToolIo => "tool_io",
            SpanKind::Prefill => "prefill",
            SpanKind::Decode => "decode",
            SpanKind::KvTransfer => "kv_transfer",
        }
    }

    pub fn parse(s: &str) -> Option<SpanKind> {
        Some(match s {
            "request" => SpanKind::Request,
            "host" => SpanKind::Host,
            "tool_io" => SpanKind::ToolIo,
            "prefill" => SpanKind::Prefill,
            "decode" => SpanKind::Decode,
            "kv_transfer" => SpanKind::KvTransfer,
            _ => return None,
        })
    }
}

/// Classify a host-pool op into its attribution kind: `tool.*` and
/// `io.*` stages are [`SpanKind::ToolIo`]; everything else that runs on
/// the host pool is [`SpanKind::Host`]. Both backends use this one
/// classifier, so the split can never drift between sim and live.
pub fn classify_host_op(op: &str) -> SpanKind {
    if op.starts_with("tool.") || op.starts_with("io.") {
        SpanKind::ToolIo
    } else {
        SpanKind::Host
    }
}

/// One timed segment of one request. Times are **modeled seconds**
/// from the run origin in both backends (the live path divides wall
/// time by its time scale; with `time_scale <= 0` raw wall seconds are
/// used — relative structure is preserved either way).
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// Request id.
    pub request: u64,
    /// DAG node (binding index) this span executes; `-1` for the
    /// request envelope. KV-transfer spans carry the *destination*
    /// node (the one whose input is in flight).
    pub node: i64,
    pub kind: SpanKind,
    /// Pipeline-group shape key (`"decode H100 tp1 pp1 b32"`), `"host"`
    /// for host-pool stages, `""` for the request envelope.
    pub group: String,
    /// Chassis the work ran on (0 for host / envelope spans).
    pub chassis: u32,
    /// Execution start (after any queueing), modeled seconds.
    pub t_start: f64,
    /// Execution end, modeled seconds.
    pub t_end: f64,
    /// The dependency node whose completion gated this span (the
    /// last-arriving input — the critical-path edge); `-1` for roots
    /// and the request envelope.
    pub parent: i64,
    /// Seconds spent queued before `t_start` (admission wait for the
    /// envelope, batcher+channel wait for LLM stages, host-pool queue
    /// for host stages, 0 for transfers — the fabric clock already
    /// serializes contention into the span itself).
    pub queue_wait: f64,
}

impl Span {
    pub fn duration_s(&self) -> f64 {
        (self.t_end - self.t_start).max(0.0)
    }

    pub fn to_json(&self) -> Json {
        crate::jobj! {
            "request" => self.request,
            "node" => self.node,
            "kind" => self.kind.as_str(),
            "group" => self.group.as_str(),
            "chassis" => self.chassis,
            "t_start" => self.t_start,
            "t_end" => self.t_end,
            "parent" => self.parent,
            "queue_wait" => self.queue_wait,
        }
    }

    pub fn from_json(j: &Json) -> Result<Span> {
        let f = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| Error::Runtime(format!("span missing numeric `{k}`")))
        };
        let kind_s = j
            .get("kind")
            .and_then(|v| v.as_str())
            .ok_or_else(|| Error::Runtime("span missing `kind`".into()))?;
        Ok(Span {
            request: f("request")? as u64,
            node: f("node")? as i64,
            kind: SpanKind::parse(kind_s)
                .ok_or_else(|| Error::Runtime(format!("unknown span kind `{kind_s}`")))?,
            group: j
                .get("group")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            chassis: f("chassis")? as u32,
            t_start: f("t_start")?,
            t_end: f("t_end")?,
            parent: f("parent")? as i64,
            queue_wait: f("queue_wait")?,
        })
    }
}

/// Shard count: recording threads (dispatcher + engine workers + host
/// workers) spread pushes across this many mutexes.
const SHARDS: usize = 8;

/// Lock-light span recorder shared by every thread of a run. Spans
/// carry a global sequence number so [`TraceSink::drain`] returns a
/// deterministic emission order regardless of which shard each landed
/// in.
#[derive(Debug, Default)]
pub struct TraceSink {
    seq: AtomicU64,
    shards: [Mutex<Vec<(u64, Span)>>; SHARDS],
}

impl TraceSink {
    pub fn new() -> Arc<TraceSink> {
        Arc::new(TraceSink::default())
    }

    pub fn record(&self, span: Span) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let mut shard = self.shards[(seq as usize) % SHARDS].lock().unwrap();
        shard.push((seq, span));
    }

    /// Spans recorded so far.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Remove and return every span in emission order.
    pub fn drain(&self) -> Vec<Span> {
        let mut all: Vec<(u64, Span)> = Vec::with_capacity(self.len());
        for shard in &self.shards {
            all.append(&mut shard.lock().unwrap());
        }
        all.sort_by_key(|(seq, _)| *seq);
        all.into_iter().map(|(_, s)| s).collect()
    }

    /// Copy of every span in emission order (non-destructive).
    pub fn spans(&self) -> Vec<Span> {
        let mut all: Vec<(u64, Span)> = Vec::with_capacity(self.len());
        for shard in &self.shards {
            all.extend(shard.lock().unwrap().iter().cloned());
        }
        all.sort_by_key(|(seq, _)| *seq);
        all.into_iter().map(|(_, s)| s).collect()
    }
}

/// Record a span iff tracing is enabled. The closure only runs when a
/// sink is attached, so the disabled fast path does no allocation and
/// no formatting work — instrumentation sites stay free when off.
#[inline]
pub fn record_with(sink: &Option<Arc<TraceSink>>, make: impl FnOnce() -> Span) {
    if let Some(s) = sink {
        s.record(make());
    }
}

/// Serialize spans as a Chrome trace-event document (Perfetto /
/// `chrome://tracing` loadable). Groups map to processes (stable pid
/// per distinct group name, named with `ph:"M"` metadata records),
/// requests map to threads, and each span becomes a `ph:"X"` complete
/// event with µs timestamps. `args` carries the full span fields for
/// lossless re-import.
pub fn to_chrome_json(spans: &[Span]) -> Json {
    use std::collections::BTreeMap;
    let mut pids: BTreeMap<&str, usize> = BTreeMap::new();
    for s in spans {
        let next = pids.len();
        pids.entry(s.group.as_str()).or_insert(next);
    }
    let mut events: Vec<Json> = Vec::with_capacity(spans.len() + pids.len());
    for (group, pid) in &pids {
        let name = if group.is_empty() { "requests" } else { group };
        events.push(crate::jobj! {
            "ph" => "M",
            "name" => "process_name",
            "pid" => *pid,
            "tid" => 0u64,
            "args" => crate::jobj! { "name" => name },
        });
    }
    for s in spans {
        events.push(crate::jobj! {
            "ph" => "X",
            "name" => s.kind.as_str(),
            "cat" => s.kind.as_str(),
            "pid" => pids[s.group.as_str()],
            "tid" => s.request,
            "ts" => s.t_start * 1e6,
            "dur" => s.duration_s() * 1e6,
            "args" => s.to_json(),
        });
    }
    crate::jobj! {
        "displayTimeUnit" => "ms",
        "traceEvents" => Json::Arr(events),
    }
}

/// Serialize spans straight into a `String`, byte-identical to
/// `to_chrome_json(spans).to_string()`. The tree builder materializes
/// every event as a [`Json`] node before writing; this path holds one
/// event tree at a time, so exporting a million-span trace allocates
/// the output string and little else. `main.rs` uses it for
/// `--trace-out`.
pub fn to_chrome_json_string(spans: &[Span]) -> String {
    use std::collections::BTreeMap;
    let mut pids: BTreeMap<&str, usize> = BTreeMap::new();
    for s in spans {
        let next = pids.len();
        pids.entry(s.group.as_str()).or_insert(next);
    }
    // Framing mirrors the compact writer: BTreeMap key order puts
    // "displayTimeUnit" before "traceEvents".
    let mut out = String::with_capacity(64 + 256 * (spans.len() + pids.len()));
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    let mut first = true;
    let mut emit = |out: &mut String, ev: Json| {
        if !first {
            out.push(',');
        }
        first = false;
        ev.write_compact(out);
    };
    for (group, pid) in &pids {
        let name = if group.is_empty() { "requests" } else { group };
        emit(
            &mut out,
            crate::jobj! {
                "ph" => "M",
                "name" => "process_name",
                "pid" => *pid,
                "tid" => 0u64,
                "args" => crate::jobj! { "name" => name },
            },
        );
    }
    for s in spans {
        emit(
            &mut out,
            crate::jobj! {
                "ph" => "X",
                "name" => s.kind.as_str(),
                "cat" => s.kind.as_str(),
                "pid" => pids[s.group.as_str()],
                "tid" => s.request,
                "ts" => s.t_start * 1e6,
                "dur" => s.duration_s() * 1e6,
                "args" => s.to_json(),
            },
        );
    }
    out.push_str("]}");
    out
}

/// Recover the `Vec<Span>` from a Chrome trace document written by
/// [`to_chrome_json`] (metadata events are skipped; `args` is
/// authoritative).
pub fn spans_from_chrome_json(doc: &Json) -> Result<Vec<Span>> {
    let events = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| Error::Runtime("trace document has no `traceEvents`".into()))?;
    let mut out = Vec::new();
    for e in events {
        if e.get("ph").and_then(|v| v.as_str()) != Some("X") {
            continue;
        }
        let args = e
            .get("args")
            .ok_or_else(|| Error::Runtime("trace event has no `args`".into()))?;
        out.push(Span::from_json(args)?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_spans() -> Vec<Span> {
        vec![
            Span {
                request: 0,
                node: -1,
                kind: SpanKind::Request,
                group: String::new(),
                chassis: 0,
                t_start: 0.0,
                t_end: 1.0,
                parent: -1,
                queue_wait: 0.05,
            },
            Span {
                request: 0,
                node: 2,
                kind: SpanKind::Prefill,
                group: "prefill H100 tp1 pp1 b8".into(),
                chassis: 0,
                t_start: 0.1,
                t_end: 0.2,
                parent: 1,
                queue_wait: 0.02,
            },
            Span {
                request: 0,
                node: 3,
                kind: SpanKind::KvTransfer,
                group: "decode Gaudi3 tp1 pp1 b32".into(),
                chassis: 1,
                t_start: 0.2,
                t_end: 0.45,
                parent: 2,
                queue_wait: 0.0,
            },
        ]
    }

    #[test]
    fn span_json_round_trips() {
        for s in sample_spans() {
            let back = Span::from_json(&s.to_json()).unwrap();
            assert_eq!(back, s);
        }
    }

    #[test]
    fn chrome_export_round_trips_and_is_byte_stable() {
        let spans = sample_spans();
        let doc = to_chrome_json(&spans);
        let text = doc.to_string();
        // Byte-stable: BTreeMap ordering makes re-serialization exact.
        let reparsed = Json::parse(&text).unwrap();
        assert_eq!(reparsed.to_string(), text);
        let back = spans_from_chrome_json(&reparsed).unwrap();
        assert_eq!(back, spans);
        // Structure: one metadata record per distinct group, µs stamps.
        let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let metas = events
            .iter()
            .filter(|e| e.get("ph").and_then(|v| v.as_str()) == Some("M"))
            .count();
        assert_eq!(metas, 3);
        let x0 = events
            .iter()
            .find(|e| e.get("ph").and_then(|v| v.as_str()) == Some("X"))
            .unwrap();
        assert_eq!(x0.get("dur").unwrap().as_f64().unwrap(), 1e6);
    }

    #[test]
    fn streaming_serializer_matches_tree_builder_bytes() {
        let spans = sample_spans();
        assert_eq!(
            to_chrome_json_string(&spans),
            to_chrome_json(&spans).to_string()
        );
        assert_eq!(to_chrome_json_string(&[]), to_chrome_json(&[]).to_string());
    }

    #[test]
    fn sink_orders_by_emission_across_shards() {
        let sink = TraceSink::new();
        let mut spans = sample_spans();
        // More spans than shards so ordering must come from seq.
        for i in 0..20u64 {
            let mut s = spans[1].clone();
            s.request = i;
            sink.record(s.clone());
            spans.push(s);
        }
        let drained = sink.drain();
        assert_eq!(drained.len(), 20);
        let ids: Vec<u64> = drained.iter().map(|s| s.request).collect();
        assert_eq!(ids, (0..20).collect::<Vec<_>>());
        assert!(sink.is_empty(), "drain must consume");
    }

    #[test]
    fn disabled_sink_skips_the_closure() {
        let sink: Option<Arc<TraceSink>> = None;
        let mut ran = false;
        record_with(&sink, || {
            ran = true;
            sample_spans().pop().unwrap()
        });
        assert!(!ran, "disabled tracing must not evaluate the span");
    }

    #[test]
    fn host_op_classifier() {
        assert_eq!(classify_host_op("tool.search"), SpanKind::ToolIo);
        assert_eq!(classify_host_op("io.input"), SpanKind::ToolIo);
        assert_eq!(classify_host_op("stt.transcribe"), SpanKind::Host);
        assert_eq!(classify_host_op("tts.synthesize"), SpanKind::Host);
    }
}
