//! Observability: counters, gauges, and latency histograms for the
//! serving path (§4.1's runtime "metrics collection").
//!
//! Lock-light: counters are atomics; histograms take a short mutex only
//! on record. A [`MetricsRegistry`] snapshot renders a flat text report
//! (exposition-format-ish) for the CLI and the e2e example.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

pub mod critical_path;
pub mod trace;

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (bit-cast f64).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket latency histogram (log-spaced, 1µs .. ~100s).
#[derive(Debug)]
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    /// Nanosecond sum: sub-µs samples (fast host stages) accumulate
    /// instead of truncating to zero.
    sum_ns: AtomicU64,
    count: AtomicU64,
}

const N_BUCKETS: usize = 40;

fn bucket_for(us: f64) -> usize {
    if us <= 1.0 {
        return 0;
    }
    // log-spaced: each bucket is ~1.585x the previous (10^0.2).
    ((us.log10() / 0.2) as usize).min(N_BUCKETS - 1)
}

/// Geometric midpoint of bucket `i` (which covers
/// `[10^(0.2i), 10^(0.2(i+1)))` µs) — an unbiased point estimate for
/// percentile reporting, unlike the upper bound which always
/// over-reports by up to 1.585x.
fn bucket_mid_us(i: usize) -> f64 {
    10f64.powf((i as f64 + 0.5) * 0.2)
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: (0..N_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn record_secs(&self, secs: f64) {
        let us = secs * 1e6;
        self.buckets[bucket_for(us)].fetch_add(1, Ordering::Relaxed);
        // u64 nanoseconds: ~584 years of accumulated busy-time headroom.
        self.sum_ns
            .fetch_add((secs * 1e9).round().max(0.0) as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64 / 1e9
    }

    /// Approximate percentile: the geometric midpoint of the bucket the
    /// target rank lands in.
    pub fn percentile_secs(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return bucket_mid_us(i) / 1e6;
            }
        }
        bucket_mid_us(N_BUCKETS - 1) / 1e6
    }
}

/// Named metrics, registered on first use.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    counters: Mutex<BTreeMap<String, std::sync::Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, std::sync::Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, std::sync::Arc<Histogram>>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> std::sync::Arc<Counter> {
        self.counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> std::sync::Arc<Gauge> {
        self.gauges
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> std::sync::Arc<Histogram> {
        self.histograms
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Per-stage latency histogram for one agent-graph op — the live
    /// DAG executor records every executed binding here (`stage_<op>`),
    /// giving the per-stage view the simulator reports via
    /// `DagDetail::node_mean_latency_s`.
    pub fn stage_histogram(&self, op: &str) -> std::sync::Arc<Histogram> {
        self.histogram(&format!("stage_{op}"))
    }

    /// Flat numeric snapshot (stable ordering) for exporters — the
    /// orchestrator summarizes a run from this, and the CLI prints it
    /// next to the timeline. Histograms contribute summary keys
    /// (`{name}_count`, `{name}_p50`, `{name}_p95`, seconds) so latency
    /// percentiles flow into timelines alongside counters/gauges.
    pub fn snapshot(&self) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        for (k, c) in self.counters.lock().unwrap().iter() {
            out.insert(k.clone(), c.get() as f64);
        }
        for (k, g) in self.gauges.lock().unwrap().iter() {
            out.insert(k.clone(), g.get());
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            out.insert(format!("{k}_count"), h.count() as f64);
            out.insert(format!("{k}_p50"), h.percentile_secs(50.0));
            out.insert(format!("{k}_p95"), h.percentile_secs(95.0));
        }
        out
    }

    /// Flat text report, stable ordering.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (k, c) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k} {}\n", c.get()));
        }
        for (k, g) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{k} {}\n", g.get()));
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            out.push_str(&format!(
                "{k}_count {}\n{k}_mean_ms {:.3}\n{k}_p50_ms {:.3}\n{k}_p95_ms {:.3}\n",
                h.count(),
                h.mean_secs() * 1e3,
                h.percentile_secs(50.0) * 1e3,
                h.percentile_secs(95.0) * 1e3
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let r = MetricsRegistry::new();
        r.counter("reqs").inc();
        r.counter("reqs").add(4);
        r.gauge("batch").set(7.5);
        assert_eq!(r.counter("reqs").get(), 5);
        assert_eq!(r.gauge("batch").get(), 7.5);
    }

    #[test]
    fn histogram_percentiles_bracket_inputs() {
        let h = Histogram::default();
        for i in 1..=1000 {
            h.record_secs(i as f64 * 1e-3); // 1ms .. 1s uniform
        }
        // The bucket-midpoint estimate sits within one log-bucket
        // (1.585x) of the true p50 = 0.5s, not biased to the bucket's
        // upper edge.
        let p50 = h.percentile_secs(50.0);
        assert!(p50 > 0.5 / 1.585 && p50 < 0.5 * 1.585, "p50={p50}");
        let p99 = h.percentile_secs(99.0);
        assert!(p99 >= p50);
        assert!((h.mean_secs() - 0.5005).abs() < 1e-6);
    }

    #[test]
    fn histogram_mean_keeps_sub_microsecond_samples() {
        // 0.4µs samples truncated to 0 under the old µs accumulator;
        // the ns sum keeps them.
        let h = Histogram::default();
        for _ in 0..1000 {
            h.record_secs(4e-7);
        }
        assert!((h.mean_secs() - 4e-7).abs() < 1e-9, "{}", h.mean_secs());
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = Histogram::default();
        assert_eq!(h.percentile_secs(50.0), 0.0);
        assert_eq!(h.mean_secs(), 0.0);
    }

    #[test]
    fn report_contains_all() {
        let r = MetricsRegistry::new();
        r.counter("a").inc();
        r.gauge("b").set(1.0);
        r.histogram("c").record_secs(0.001);
        let rep = r.report();
        assert!(rep.contains("a 1"));
        assert!(rep.contains("b 1"));
        assert!(rep.contains("c_count 1"));
    }

    #[test]
    fn snapshot_is_flat_and_numeric() {
        let r = MetricsRegistry::new();
        r.counter("orch_decisions").add(3);
        r.gauge("orch_decode_util").set(0.75);
        r.histogram("latency").record_secs(0.01);
        let s = r.snapshot();
        assert_eq!(s["orch_decisions"], 3.0);
        assert_eq!(s["orch_decode_util"], 0.75);
        // Histograms surface as flat summary keys, never as a nested
        // entry under their bare name.
        assert!(!s.contains_key("latency"));
        assert_eq!(s["latency_count"], 1.0);
        assert!(s["latency_p50"] > 0.005 && s["latency_p50"] < 0.02);
        assert!(s["latency_p95"] >= s["latency_p50"]);
    }

    #[test]
    fn concurrent_counting() {
        let r = std::sync::Arc::new(MetricsRegistry::new());
        let c = r.counter("x");
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.get(), 8000);
    }
}
