//! Agent workload library: ready-made graphs for the paper's examples.
//!
//! * [`voice_agent`] — Figure 2's conversational voice agent (STT →
//!   LLM with a bounded web-search loop → TTS);
//! * [`rag_agent`] — retrieval-augmented generation (memory lookup +
//!   context assembly before the LLM);
//! * [`langchain_style_agent`] — Figure 7(a)'s memory + Search() +
//!   Calculator() agent, as lowered in Figure 7(b);
//! * [`patterns`] — the Figure 1 taxonomy builders: single, peer
//!   network, supervisor, agent-as-tool, hierarchical, custom.

pub mod patterns;

use crate::ir::attr::Attr;
use crate::ir::graph::Graph;
use crate::ir::GraphBuilder;

/// Figure 2: conversational voice agent.
///
/// The "search until enough context" feedback loop is expressed as a
/// `ctrl.loop` region with bounded trips (§3.1 bounded unrolling).
pub fn voice_agent(model: &str, isl: i64, osl: i64) -> Graph {
    let mut b = GraphBuilder::new("voice_agent");
    let audio = b.op_with("io.input", &[], &[("modality", "audio".into())]);
    let text = b.op_with(
        "stt.transcribe",
        &[audio],
        &[("model", "whisper-small".into())],
    );

    // Search loop: LLM decides whether it needs more context.
    let mut inner = GraphBuilder::new("search_loop");
    let q = inner.op("io.input", &[]);
    let hits = inner.op_with("tool.lookup", &[q], &[("tool", "web_search".into())]);
    let merged = inner.op_with("gp.compute", &[hits], &[("op", "merge_context".into())]);
    inner.output(merged);
    let searched = b.region_op(
        "ctrl.loop",
        &[text],
        &[("max_trips", Attr::Int(3)), ("cond", "needs_context".into())],
        inner.finish(),
    );

    let answer = b.op_with(
        "llm.infer",
        &[searched],
        &[
            ("model", model.into()),
            ("isl", Attr::Int(isl)),
            ("osl", Attr::Int(osl)),
        ],
    );
    let speech = b.op_with(
        "tts.synthesize",
        &[answer],
        &[("voice", "en-US".into())],
    );
    b.op("io.output", &[speech]);
    b.output(speech);
    b.finish()
}

/// Retrieval-augmented generation agent (Table 1's memory-lookup path).
pub fn rag_agent(model: &str, isl: i64, osl: i64, top_k: i64) -> Graph {
    let mut b = GraphBuilder::new("rag_agent");
    let query = b.op_with("io.input", &[], &[("modality", "text".into())]);
    let embedded = b.op_with("gp.compute", &[query], &[("op", "embed_query".into())]);
    let docs = b.op_with(
        "mem.lookup",
        &[embedded],
        &[("store", "vector_db".into()), ("top_k", Attr::Int(top_k))],
    );
    let ctx = b.op_with(
        "gp.compute",
        &[docs],
        &[("op", "assemble_context".into())],
    );
    let out = b.op_with(
        "llm.infer",
        &[ctx],
        &[
            ("model", model.into()),
            ("isl", Attr::Int(isl)),
            ("osl", Attr::Int(osl)),
        ],
    );
    b.op_with("obs.store", &[out], &[("kind", "episodic".into())]);
    b.op("io.output", &[out]);
    b.output(out);
    b.finish()
}

/// Figure 7(a): LangChain-style agent with memory and two tools.
pub fn langchain_style_agent(model: &str) -> Graph {
    let mut b = GraphBuilder::new("langchain_agent");
    let query = b.op("io.input", &[]);
    let memory = b.op_with(
        "mem.lookup",
        &[query],
        &[("store", "conversation_memory".into())],
    );
    let planned = b.op_with(
        "ctrl.plan",
        &[query, memory],
        &[("planner", "react".into())],
    );
    let search = b.op_with("tool.call", &[planned], &[("tool", "Search".into())]);
    let calc = b.op_with("tool.call", &[planned], &[("tool", "Calculator".into())]);
    let gathered = b.op("ctrl.merge", &[search, calc]);
    let out = b.op_with(
        "llm.infer",
        &[planned, gathered],
        &[("model", model.into()), ("isl", Attr::Int(1024)), ("osl", Attr::Int(256))],
    );
    b.op_with("mem.store", &[out], &[("store", "conversation_memory".into())]);
    b.op("io.output", &[out]);
    b.output(out);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::passes::PassManager;
    use crate::ir::verifier::verify;

    #[test]
    fn voice_agent_verifies_and_matches_fig2() {
        let g = voice_agent("8b-fp16", 512, 256);
        verify(&g).unwrap();
        for op in [
            "io.input",
            "stt.transcribe",
            "ctrl.loop",
            "llm.infer",
            "tts.synthesize",
            "io.output",
        ] {
            assert!(g.contains_op(op), "missing {op}");
        }
        // The search branch lives inside the loop region.
        assert!(g.contains_op("tool.lookup"));
    }

    #[test]
    fn rag_agent_verifies() {
        let g = rag_agent("70b-fp8", 2048, 256, 8);
        verify(&g).unwrap();
        assert!(g.contains_op("mem.lookup"));
        assert!(g.contains_op("obs.store"));
    }

    #[test]
    fn langchain_agent_lowers_like_fig7() {
        let mut g = langchain_style_agent("8b-fp16");
        verify(&g).unwrap();
        let mut pm = PassManager::standard();
        pm.run(&mut g).unwrap();
        // Figure 7(c): llm split, tools split.
        assert!(g.contains_op("llm.prefill"));
        assert!(g.contains_op("llm.decode"));
        assert!(g.contains_op("tool.lookup"));
        assert!(g.contains_op("tool.compute"));
        assert!(!g.contains_op("tool.call"));
    }

    #[test]
    fn agents_round_trip_through_text() {
        for g in [
            voice_agent("8b-fp16", 512, 128),
            rag_agent("8b-fp16", 1024, 128, 4),
            langchain_style_agent("70b-fp16"),
        ] {
            let text = crate::ir::printer::print(&g);
            let g2 = crate::ir::parser::parse(&text).unwrap();
            verify(&g2).unwrap();
            assert_eq!(crate::ir::printer::print(&g2), text);
        }
    }
}
