//! Figure 1's taxonomy of agentic architectural patterns, as graph
//! builders: (a) single agent, (b) peer-to-peer network, (c) supervisor,
//! (d) agent-as-tool, (e) hierarchical, (f) custom.

use crate::ir::attr::Attr;
use crate::ir::graph::Graph;
use crate::ir::GraphBuilder;

/// A leaf agent body: input → llm → yield.
fn leaf_agent(name: &str, model: &str) -> Graph {
    let mut b = GraphBuilder::new(name);
    let x = b.op("io.input", &[]);
    let y = b.op_with("llm.infer", &[x], &[("model", model.into())]);
    b.output(y);
    b.finish()
}

/// (a) Single agent invoking tools directly.
pub fn single_agent(model: &str, tools: &[&str]) -> Graph {
    let mut b = GraphBuilder::new("single_agent");
    let x = b.op("io.input", &[]);
    let plan = b.op_with("ctrl.plan", &[x], &[("planner", "react".into())]);
    let mut outs = vec![plan];
    for t in tools {
        outs.push(b.op_with("tool.call", &[plan], &[("tool", (*t).into())]));
    }
    let merged = b.op("ctrl.merge", &outs);
    let y = b.op_with("llm.infer", &[merged], &[("model", model.into())]);
    b.op("io.output", &[y]);
    b.output(y);
    b.finish()
}

/// (b) Peer-to-peer network: `n` agents exchange and merge.
pub fn peer_network(model: &str, n: usize) -> Graph {
    let mut b = GraphBuilder::new("peer_network");
    let x = b.op("io.input", &[]);
    let peers: Vec<_> = (0..n)
        .map(|i| {
            b.region_op(
                "agent.graph",
                &[x],
                &[("role", format!("peer_{i}").into())],
                leaf_agent(&format!("peer_{i}"), model),
            )
        })
        .collect();
    let merged = b.op("ctrl.merge", &peers);
    b.op("io.output", &[merged]);
    b.output(merged);
    b.finish()
}

/// (c) Supervisor dispatching to subordinates.
pub fn supervisor(model: &str, workers: usize) -> Graph {
    let mut b = GraphBuilder::new("supervisor");
    let x = b.op("io.input", &[]);
    let sup = b.op_with(
        "ctrl.plan",
        &[x],
        &[("planner", "supervisor".into()), ("model", model.into())],
    );
    let subs: Vec<_> = (0..workers)
        .map(|i| {
            b.region_op(
                "agent.graph",
                &[sup],
                &[("role", format!("worker_{i}").into())],
                leaf_agent(&format!("worker_{i}"), model),
            )
        })
        .collect();
    let merged = b.op("ctrl.merge", &subs);
    let y = b.op_with("llm.infer", &[merged], &[("model", model.into())]);
    b.op("io.output", &[y]);
    b.output(y);
    b.finish()
}

/// (d) Agent-as-tool: the supervisor is invoked like a tool.
pub fn agent_as_tool(model: &str) -> Graph {
    let mut b = GraphBuilder::new("agent_as_tool");
    let x = b.op("io.input", &[]);
    let helper = b.region_op(
        "agent.graph",
        &[x],
        &[("role", "tool_agent".into()), ("invoked_as", "tool".into())],
        leaf_agent("helper", model),
    );
    let y = b.op_with("llm.infer", &[x, helper], &[("model", model.into())]);
    b.op("io.output", &[y]);
    b.output(y);
    b.finish()
}

/// (e) Hierarchical: supervisors of supervisors, `depth` layers with
/// `fanout` children each.
pub fn hierarchical(model: &str, depth: usize, fanout: usize) -> Graph {
    fn level(model: &str, depth: usize, fanout: usize, tag: String) -> Graph {
        if depth == 0 {
            return leaf_agent(&format!("leaf_{tag}"), model);
        }
        let mut b = GraphBuilder::new(&format!("tier_{tag}"));
        let x = b.op("io.input", &[]);
        let plan = b.op_with("ctrl.plan", &[x], &[("planner", "supervisor".into())]);
        let kids: Vec<_> = (0..fanout)
            .map(|i| {
                b.region_op(
                    "agent.graph",
                    &[plan],
                    &[("role", format!("child_{tag}_{i}").into())],
                    level(model, depth - 1, fanout, format!("{tag}_{i}")),
                )
            })
            .collect();
        let merged = b.op("ctrl.merge", &kids);
        b.output(merged);
        b.finish()
    }
    let mut b = GraphBuilder::new("hierarchical");
    let x = b.op("io.input", &[]);
    let root = b.region_op(
        "agent.graph",
        &[x],
        &[("role", "root".into())],
        level(model, depth, fanout, "r".into()),
    );
    b.op("io.output", &[root]);
    b.output(root);
    b.finish()
}

/// (f) Custom graph: a diamond with a feedback loop and mixed ops.
pub fn custom(model: &str) -> Graph {
    let mut b = GraphBuilder::new("custom");
    let x = b.op("io.input", &[]);
    let l = b.op_with("llm.infer", &[x], &[("model", model.into())]);
    let t = b.op_with("tool.call", &[x], &[("tool", "db".into())]);
    let joined = b.op("ctrl.merge", &[l, t]);

    let mut refine = GraphBuilder::new("refine");
    let i = refine.op("io.input", &[]);
    let r = refine.op_with("llm.infer", &[i], &[("model", model.into())]);
    refine.output(r);
    let refined = b.region_op(
        "ctrl.loop",
        &[joined],
        &[("max_trips", Attr::Int(2)), ("cond", "not_good_enough".into())],
        refine.finish(),
    );
    b.op("io.output", &[refined]);
    b.output(refined);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::verifier::verify;

    #[test]
    fn all_patterns_verify() {
        for g in [
            single_agent("8b-fp16", &["search", "calculator"]),
            peer_network("8b-fp16", 3),
            supervisor("8b-fp16", 4),
            agent_as_tool("8b-fp16"),
            hierarchical("8b-fp16", 2, 2),
            custom("8b-fp16"),
        ] {
            verify(&g).unwrap_or_else(|e| panic!("{}: {e}", g.name));
        }
    }

    #[test]
    fn hierarchy_size_grows_with_fanout() {
        let small = hierarchical("8b-fp16", 1, 2);
        let big = hierarchical("8b-fp16", 2, 3);
        assert!(big.size() > small.size());
        // depth-2/fanout-3 has 3 mid-tier agents × 3 leaves = 9 leaves.
        let leaves = count_op(&big, "llm.infer");
        assert_eq!(leaves, 9);
    }

    fn count_op(g: &Graph, op: &str) -> usize {
        g.op_names().iter().filter(|o| *o == op).count()
    }

    #[test]
    fn peer_network_has_n_peers() {
        let g = peer_network("8b-fp16", 5);
        assert_eq!(count_op(&g, "agent.graph"), 5);
    }

    #[test]
    fn patterns_round_trip() {
        for g in [supervisor("8b-fp16", 2), hierarchical("8b-fp16", 1, 2)] {
            let text = crate::ir::printer::print(&g);
            let g2 = crate::ir::parser::parse(&text).unwrap();
            assert_eq!(crate::ir::printer::print(&g2), text);
        }
    }
}
