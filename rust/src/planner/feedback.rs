//! Runtime cost feedback (Figure 6's "runtime resource feedback" edge):
//! observed latencies refine the planner's profiled t_ij estimates via
//! exponentially-weighted moving averages, keyed by (op, class).

use std::collections::BTreeMap;

/// EWMA latency profile store.
#[derive(Debug)]
pub struct ProfileStore {
    alpha: f64,
    entries: BTreeMap<(String, String), ProfileEntry>,
}

#[derive(Debug, Clone)]
struct ProfileEntry {
    ewma_s: f64,
    samples: u64,
}

impl ProfileStore {
    /// `alpha` = weight of each new observation (0 < alpha <= 1).
    pub fn new(alpha: f64) -> ProfileStore {
        assert!(alpha > 0.0 && alpha <= 1.0);
        ProfileStore {
            alpha,
            entries: BTreeMap::new(),
        }
    }

    /// Record an observed latency for (op, hardware class).
    pub fn observe(&mut self, op: &str, class: &str, latency_s: f64) {
        let key = (op.to_string(), class.to_string());
        match self.entries.get_mut(&key) {
            None => {
                self.entries.insert(
                    key,
                    ProfileEntry {
                        ewma_s: latency_s,
                        samples: 1,
                    },
                );
            }
            Some(e) => {
                e.ewma_s = self.alpha * latency_s + (1.0 - self.alpha) * e.ewma_s;
                e.samples += 1;
            }
        }
    }

    /// Current estimate, falling back to `default_s` when unobserved.
    pub fn estimate(&self, op: &str, class: &str, default_s: f64) -> f64 {
        self.entries
            .get(&(op.to_string(), class.to_string()))
            .map(|e| e.ewma_s)
            .unwrap_or(default_s)
    }

    pub fn samples(&self, op: &str, class: &str) -> u64 {
        self.entries
            .get(&(op.to_string(), class.to_string()))
            .map(|e| e.samples)
            .unwrap_or(0)
    }

    /// Ops whose observed latency deviates from `expected` by more than
    /// `ratio` — candidates for replanning.
    pub fn drifted(
        &self,
        expected: &BTreeMap<(String, String), f64>,
        ratio: f64,
    ) -> Vec<(String, String, f64, f64)> {
        let mut out = Vec::new();
        for ((op, class), e) in &self.entries {
            if let Some(&exp) = expected.get(&(op.clone(), class.clone())) {
                if exp > 0.0 && (e.ewma_s / exp > ratio || exp / e.ewma_s > ratio) {
                    out.push((op.clone(), class.clone(), exp, e.ewma_s));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_observation_seeds() {
        let mut p = ProfileStore::new(0.2);
        p.observe("llm.prefill", "H100", 0.05);
        assert_eq!(p.estimate("llm.prefill", "H100", 9.9), 0.05);
        assert_eq!(p.samples("llm.prefill", "H100"), 1);
    }

    #[test]
    fn ewma_converges_toward_new_level() {
        let mut p = ProfileStore::new(0.3);
        p.observe("op", "X", 0.1);
        for _ in 0..50 {
            p.observe("op", "X", 0.2);
        }
        let est = p.estimate("op", "X", 0.0);
        assert!((est - 0.2).abs() < 1e-3, "est={est}");
    }

    #[test]
    fn default_when_unobserved() {
        let p = ProfileStore::new(0.5);
        assert_eq!(p.estimate("nope", "X", 1.23), 1.23);
    }

    #[test]
    fn drift_detection() {
        let mut p = ProfileStore::new(1.0);
        p.observe("llm.decode", "A40", 0.5);
        p.observe("gp.compute", "CPU", 0.005);
        let mut expected = BTreeMap::new();
        expected.insert(("llm.decode".to_string(), "A40".to_string()), 0.1);
        expected.insert(("gp.compute".to_string(), "CPU".to_string()), 0.005);
        let drifted = p.drifted(&expected, 2.0);
        assert_eq!(drifted.len(), 1);
        assert_eq!(drifted[0].0, "llm.decode");
    }

    #[test]
    #[should_panic]
    fn bad_alpha_panics() {
        let _ = ProfileStore::new(0.0);
    }
}
