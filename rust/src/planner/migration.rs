//! Migration planning: when the optimizer's placement moves, produce a
//! safe drain → transfer → activate step sequence (§4.1 "workload
//! migration"). Steps are ordered so capacity never goes negative:
//! activations precede the drains they replace.
//!
//! Duration estimates price the KV motion over the *same* contended
//! fabric model the simulator uses ([`crate::transport::fabric`]): one
//! transfer per drained decode pipeline, spread across source NICs, all
//! issued together — per-link bandwidth and FIFO queueing set the
//! completion time, so the planner's migration cost and the simulator's
//! observed cost agree.

use crate::plan::{ExecutionPlan, Role};
use crate::transport::fabric::{Fabric, NodeAddr};
use crate::util::json::Json;
use crate::{jobj, Error, Result};

/// One migration action.
#[derive(Debug, Clone, PartialEq)]
pub enum MigrationStep {
    /// Bring up a pipeline of `count` devices of `device` for `role`.
    Activate {
        device: String,
        role: String,
        count: u32,
    },
    /// Move a session's KV bytes between nodes.
    TransferKv { bytes: f64, from: String, to: String },
    /// Stop routing to, then tear down, a pipeline.
    Drain {
        device: String,
        role: String,
        count: u32,
    },
}

impl MigrationStep {
    pub fn to_json(&self) -> Json {
        match self {
            MigrationStep::Activate {
                device,
                role,
                count,
            } => jobj! {
                "kind" => "activate",
                "device" => device.clone(),
                "role" => role.clone(),
                "count" => *count,
            },
            MigrationStep::TransferKv { bytes, from, to } => jobj! {
                "kind" => "transfer_kv",
                "bytes" => *bytes,
                "from" => from.clone(),
                "to" => to.clone(),
            },
            MigrationStep::Drain {
                device,
                role,
                count,
            } => jobj! {
                "kind" => "drain",
                "device" => device.clone(),
                "role" => role.clone(),
                "count" => *count,
            },
        }
    }

    pub fn from_json(j: &Json) -> Result<MigrationStep> {
        let get_str = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(|v| v.as_str())
                .map(|s| s.to_string())
                .ok_or_else(|| Error::Config(format!("migration step missing `{k}`")))
        };
        match j.get("kind").and_then(|v| v.as_str()) {
            Some("activate") => Ok(MigrationStep::Activate {
                device: get_str("device")?,
                role: get_str("role")?,
                count: j.get("count").and_then(|v| v.as_u64()).ok_or_else(|| {
                    Error::Config("migration step missing `count`".into())
                })? as u32,
            }),
            Some("transfer_kv") => Ok(MigrationStep::TransferKv {
                bytes: j.get("bytes").and_then(|v| v.as_f64()).ok_or_else(|| {
                    Error::Config("migration step missing `bytes`".into())
                })?,
                from: get_str("from")?,
                to: get_str("to")?,
            }),
            Some("drain") => Ok(MigrationStep::Drain {
                device: get_str("device")?,
                role: get_str("role")?,
                count: j.get("count").and_then(|v| v.as_u64()).ok_or_else(|| {
                    Error::Config("migration step missing `count`".into())
                })? as u32,
            }),
            other => Err(Error::Config(format!(
                "unknown migration step kind {other:?}"
            ))),
        }
    }
}

/// A role's worth of capacity (device name → pipeline count).
pub type RoleMap = std::collections::BTreeMap<(String, String), u32>;

/// Lower a plan's pipeline fleet to the migration planner's capacity
/// view: (device, role) → total replicas.
pub fn role_map_of(plan: &ExecutionPlan) -> RoleMap {
    let mut m = RoleMap::new();
    for p in &plan.pipelines {
        *m.entry((p.device.clone(), p.role.name().to_string()))
            .or_insert(0) += p.replicas;
    }
    m
}

/// Total replicas a plan deploys for one role.
pub fn role_replicas(plan: &ExecutionPlan, role: Role) -> u32 {
    plan.pipelines
        .iter()
        .filter(|p| p.role == role)
        .map(|p| p.replicas)
        .sum()
}

/// Fixed bring-up/tear-down overhead per migration, seconds (weight
/// loading, router reprogramming) — on top of the fabric-priced KV
/// motion.
pub const MIGRATION_OVERHEAD_S: f64 = 1.0;

/// A full migration plan with a cost estimate.
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationPlan {
    pub steps: Vec<MigrationStep>,
    /// KV bytes that must move.
    pub kv_bytes: f64,
    /// Estimated wall time to complete, seconds.
    pub est_duration_s: f64,
}

impl MigrationPlan {
    pub fn to_json(&self) -> Json {
        jobj! {
            "steps" => Json::Arr(self.steps.iter().map(|s| s.to_json()).collect()),
            "kv_bytes" => self.kv_bytes,
            "est_duration_s" => self.est_duration_s,
        }
    }

    pub fn from_json(j: &Json) -> Result<MigrationPlan> {
        let steps = j
            .get("steps")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| Error::Config("migration plan missing `steps`".into()))?
            .iter()
            .map(MigrationStep::from_json)
            .collect::<Result<Vec<_>>>()?;
        let num = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| Error::Config(format!("migration plan missing `{k}`")))
        };
        Ok(MigrationPlan {
            steps,
            kv_bytes: num("kv_bytes")?,
            est_duration_s: num("est_duration_s")?,
        })
    }
}

/// Diff two fleet layouts into an ordered step list.
///
/// `kv_per_drained_pipeline` prices the state that must leave each
/// drained decode pipeline (prefill pipelines are stateless). The KV
/// motion is priced over `fabric`: one transfer per drained pipeline,
/// spread round-robin across source NICs and issued concurrently, so
/// per-link bandwidth *and* contention (several drains sharing a NIC)
/// both show up in `est_duration_s`.
pub fn plan_migration(
    current: &RoleMap,
    target: &RoleMap,
    kv_per_drained_pipeline: f64,
    fabric: &Fabric,
) -> MigrationPlan {
    let mut steps = Vec::new();
    let mut kv_bytes = 0.0;
    let mut drained_decode: u32 = 0;

    // 1. Activations first (make-before-break).
    for ((device, role), want) in target {
        let have = current.get(&(device.clone(), role.clone())).copied().unwrap_or(0);
        if *want > have {
            steps.push(MigrationStep::Activate {
                device: device.clone(),
                role: role.clone(),
                count: want - have,
            });
        }
    }
    // 2. KV transfers out of shrinking decode pipelines.
    for ((device, role), have) in current {
        let want = target.get(&(device.clone(), role.clone())).copied().unwrap_or(0);
        if *have > want && role == "decode" {
            let n = have - want;
            let moved = n as f64 * kv_per_drained_pipeline;
            kv_bytes += moved;
            drained_decode += n;
            steps.push(MigrationStep::TransferKv {
                bytes: moved,
                from: device.clone(),
                to: "fleet".into(),
            });
        }
    }
    // 3. Drains last.
    for ((device, role), have) in current {
        let want = target.get(&(device.clone(), role.clone())).copied().unwrap_or(0);
        if *have > want {
            steps.push(MigrationStep::Drain {
                device: device.clone(),
                role: role.clone(),
                count: have - want,
            });
        }
    }

    // Price the KV motion over a private copy of the fabric (no
    // reservation side effects leak to the caller).
    let mut f = fabric.clone();
    f.reset();
    let n_chassis = f.n_chassis.max(1);
    let mut done = 0.0f64;
    for i in 0..drained_decode {
        let from = NodeAddr {
            chassis: i % n_chassis,
            slot: 0,
        };
        let to = NodeAddr {
            chassis: (i + 1) % n_chassis,
            slot: 0,
        };
        if let Ok(t) = f.transfer(from, to, kv_per_drained_pipeline, 0.0) {
            done = done.max(t);
        }
    }

    MigrationPlan {
        steps,
        kv_bytes,
        est_duration_s: done + MIGRATION_OVERHEAD_S,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn role_map(entries: &[(&str, &str, u32)]) -> RoleMap {
        entries
            .iter()
            .map(|(d, r, n)| ((d.to_string(), r.to_string()), *n))
            .collect()
    }

    fn fabric() -> Fabric {
        // 4 chassis, 900 GB/s scale-up, 400 Gbit RoCE NICs.
        Fabric::new(4, 8, 900.0, 400.0)
    }

    #[test]
    fn activation_before_drain() {
        let cur = role_map(&[("H100", "decode", 2)]);
        let tgt = role_map(&[("Gaudi3", "decode", 2)]);
        let plan = plan_migration(&cur, &tgt, 1e9, &fabric());
        let first_activate = plan
            .steps
            .iter()
            .position(|s| matches!(s, MigrationStep::Activate { .. }))
            .unwrap();
        let first_drain = plan
            .steps
            .iter()
            .position(|s| matches!(s, MigrationStep::Drain { .. }))
            .unwrap();
        assert!(first_activate < first_drain);
        assert_eq!(plan.kv_bytes, 2e9);
        assert!(plan.est_duration_s > MIGRATION_OVERHEAD_S);
    }

    #[test]
    fn no_change_no_steps() {
        let cur = role_map(&[("H100", "prefill", 1), ("Gaudi3", "decode", 2)]);
        let plan = plan_migration(&cur, &cur, 1e9, &fabric());
        assert!(plan.steps.is_empty());
        assert_eq!(plan.kv_bytes, 0.0);
        assert_eq!(plan.est_duration_s, MIGRATION_OVERHEAD_S);
    }

    #[test]
    fn partial_shrink_moves_partial_kv() {
        let cur = role_map(&[("Gaudi3", "decode", 4)]);
        let tgt = role_map(&[("Gaudi3", "decode", 3)]);
        let plan = plan_migration(&cur, &tgt, 5e8, &fabric());
        assert_eq!(plan.kv_bytes, 5e8);
        assert!(plan
            .steps
            .iter()
            .any(|s| matches!(s, MigrationStep::Drain { count: 1, .. })));
    }

    #[test]
    fn prefill_drain_moves_no_kv() {
        let cur = role_map(&[("H100", "prefill", 2)]);
        let tgt = role_map(&[("H100", "prefill", 1)]);
        let plan = plan_migration(&cur, &tgt, 1e9, &fabric());
        assert_eq!(plan.kv_bytes, 0.0);
        assert_eq!(plan.est_duration_s, MIGRATION_OVERHEAD_S);
    }

    #[test]
    fn duration_follows_fabric_bandwidth_and_contention() {
        // 1 GB per drained pipeline over a 400 Gbit (50 GB/s) NIC path:
        // two NIC hops ≈ 40 ms per transfer when uncontended.
        let cur = role_map(&[("Gaudi3", "decode", 2)]);
        let tgt = role_map(&[("Gaudi3", "decode", 1)]);
        let one = plan_migration(&cur, &tgt, 1e9, &fabric());
        let xfer_one = one.est_duration_s - MIGRATION_OVERHEAD_S;
        assert!(xfer_one > 0.02 && xfer_one < 0.2, "xfer={xfer_one}");

        // A fatter NIC moves the same KV faster.
        let fat = Fabric::new(4, 8, 900.0, 1600.0);
        let fast = plan_migration(&cur, &tgt, 1e9, &fat);
        assert!(fast.est_duration_s < one.est_duration_s);

        // Many drains on a tiny fabric contend for the same NICs: the
        // aggregate slows down vs a single drain of the same per-pipe KV.
        let tiny = Fabric::new(2, 8, 900.0, 400.0);
        let cur8 = role_map(&[("Gaudi3", "decode", 8)]);
        let tgt0 = role_map(&[("Gaudi3", "decode", 1)]);
        let many = plan_migration(&cur8, &tgt0, 1e9, &tiny);
        let single = plan_migration(&cur, &tgt, 1e9, &tiny);
        assert!(
            many.est_duration_s > single.est_duration_s,
            "contention must slow the fleet-wide drain: {} vs {}",
            many.est_duration_s,
            single.est_duration_s
        );
    }

    #[test]
    fn role_map_lowering_and_json_round_trip() {
        let plan = crate::plan::tests::tiny_plan();
        let m = role_map_of(&plan);
        assert_eq!(m[&("H100".to_string(), "prefill".to_string())], 1);
        assert_eq!(m[&("Gaudi3".to_string(), "decode".to_string())], 2);
        assert_eq!(role_replicas(&plan, Role::Prefill), 1);
        assert_eq!(role_replicas(&plan, Role::Decode), 2);

        let cur = role_map(&[("H100", "decode", 2), ("H100", "prefill", 1)]);
        let tgt = role_map(&[("Gaudi3", "decode", 3), ("H100", "prefill", 1)]);
        let mp = plan_migration(&cur, &tgt, 2e9, &fabric());
        let back =
            MigrationPlan::from_json(&Json::parse(&mp.to_json().pretty()).unwrap()).unwrap();
        assert_eq!(back, mp);
    }
}
