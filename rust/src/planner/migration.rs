//! Migration planning: when the optimizer's placement moves, produce a
//! safe drain → transfer → activate step sequence (§4.1 "workload
//! migration"). Steps are ordered so capacity never goes negative:
//! activations precede the drains they replace.
//!
//! Duration estimates price the KV motion on the *same* contended
//! [`TransferClock`](crate::transport::fabric::TransferClock) both
//! execution backends drive: one transfer per drained decode pipeline,
//! all issued together, each paying per-link bandwidth, latency, and
//! FIFO queueing — so the planner's migration cost and the backends'
//! observed cost agree. Cross-group moves carry real chassis routes
//! ([`KvRoute`]): the drained group's chassis to the surviving group
//! that absorbs its sessions (see `orchestrator::lower_diff`), instead
//! of the old synthetic round-robin spread.

use std::collections::BTreeMap;

use crate::plan::{ExecutionPlan, Role};
use crate::transport::fabric::{Fabric, TransferClock};
use crate::util::json::Json;
use crate::{jobj, Error, Result};

/// One migration action.
#[derive(Debug, Clone, PartialEq)]
pub enum MigrationStep {
    /// Bring up a pipeline of `count` devices of `device` for `role`.
    Activate {
        device: String,
        role: String,
        count: u32,
    },
    /// Move a session's KV bytes between nodes.
    TransferKv { bytes: f64, from: String, to: String },
    /// Stop routing to, then tear down, a pipeline.
    Drain {
        device: String,
        role: String,
        count: u32,
    },
}

impl MigrationStep {
    pub fn to_json(&self) -> Json {
        match self {
            MigrationStep::Activate {
                device,
                role,
                count,
            } => jobj! {
                "kind" => "activate",
                "device" => device.clone(),
                "role" => role.clone(),
                "count" => *count,
            },
            MigrationStep::TransferKv { bytes, from, to } => jobj! {
                "kind" => "transfer_kv",
                "bytes" => *bytes,
                "from" => from.clone(),
                "to" => to.clone(),
            },
            MigrationStep::Drain {
                device,
                role,
                count,
            } => jobj! {
                "kind" => "drain",
                "device" => device.clone(),
                "role" => role.clone(),
                "count" => *count,
            },
        }
    }

    pub fn from_json(j: &Json) -> Result<MigrationStep> {
        let get_str = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(|v| v.as_str())
                .map(|s| s.to_string())
                .ok_or_else(|| Error::Config(format!("migration step missing `{k}`")))
        };
        match j.get("kind").and_then(|v| v.as_str()) {
            Some("activate") => Ok(MigrationStep::Activate {
                device: get_str("device")?,
                role: get_str("role")?,
                count: j.get("count").and_then(|v| v.as_u64()).ok_or_else(|| {
                    Error::Config("migration step missing `count`".into())
                })? as u32,
            }),
            Some("transfer_kv") => Ok(MigrationStep::TransferKv {
                bytes: j.get("bytes").and_then(|v| v.as_f64()).ok_or_else(|| {
                    Error::Config("migration step missing `bytes`".into())
                })?,
                from: get_str("from")?,
                to: get_str("to")?,
            }),
            Some("drain") => Ok(MigrationStep::Drain {
                device: get_str("device")?,
                role: get_str("role")?,
                count: j.get("count").and_then(|v| v.as_u64()).ok_or_else(|| {
                    Error::Config("migration step missing `count`".into())
                })? as u32,
            }),
            other => Err(Error::Config(format!(
                "unknown migration step kind {other:?}"
            ))),
        }
    }
}

/// A role's worth of capacity (device name → pipeline count).
pub type RoleMap = std::collections::BTreeMap<(String, String), u32>;

/// Lower a plan's pipeline fleet to the migration planner's capacity
/// view: (device, role) → total replicas.
pub fn role_map_of(plan: &ExecutionPlan) -> RoleMap {
    let mut m = RoleMap::new();
    for p in &plan.pipelines {
        *m.entry((p.device.clone(), p.role.name().to_string()))
            .or_insert(0) += p.replicas;
    }
    m
}

/// Total replicas a plan deploys for one role.
pub fn role_replicas(plan: &ExecutionPlan, role: Role) -> u32 {
    plan.pipelines
        .iter()
        .filter(|p| p.role == role)
        .map(|p| p.replicas)
        .sum()
}

/// Fixed bring-up/tear-down overhead per migration, seconds (weight
/// loading, router reprogramming) — on top of the fabric-priced KV
/// motion.
pub const MIGRATION_OVERHEAD_S: f64 = 1.0;

/// A full migration plan with a cost estimate.
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationPlan {
    pub steps: Vec<MigrationStep>,
    /// KV bytes that must move.
    pub kv_bytes: f64,
    /// Estimated wall time to complete, seconds.
    pub est_duration_s: f64,
}

impl MigrationPlan {
    pub fn to_json(&self) -> Json {
        jobj! {
            "steps" => Json::Arr(self.steps.iter().map(|s| s.to_json()).collect()),
            "kv_bytes" => self.kv_bytes,
            "est_duration_s" => self.est_duration_s,
        }
    }

    pub fn from_json(j: &Json) -> Result<MigrationPlan> {
        let steps = j
            .get("steps")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| Error::Config("migration plan missing `steps`".into()))?
            .iter()
            .map(MigrationStep::from_json)
            .collect::<Result<Vec<_>>>()?;
        let num = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| Error::Config(format!("migration plan missing `{k}`")))
        };
        Ok(MigrationPlan {
            steps,
            kv_bytes: num("kv_bytes")?,
            est_duration_s: num("est_duration_s")?,
        })
    }
}

/// Where one drained group's KV travels: source chassis (the drained
/// group's **top** replica — the `j`-th drained replica of the group
/// prices from `from_chassis - j`, matching the simulator's
/// retire-top-replicas-first drain, so concurrent drains spread over
/// distinct NICs instead of FIFO-serializing on one link) to the
/// chassis of the surviving same-role capacity that absorbs its
/// sessions, plus the surviving group's label for the
/// [`MigrationStep::TransferKv`] destination.
#[derive(Debug, Clone)]
pub struct KvRoute {
    pub from_chassis: u32,
    pub to_chassis: u32,
    /// Human-readable destination (the absorbing group's shape key).
    pub to_label: String,
}

/// Diff two fleet layouts into an ordered step list.
///
/// `kv_per_drained_pipeline` prices the state that must leave each
/// drained decode pipeline (prefill pipelines are stateless). The KV
/// motion is priced on a private [`TransferClock`] over `fabric`: one
/// transfer per drained pipeline, all issued together, so per-link
/// bandwidth *and* contention (several drains sharing a NIC) both show
/// up in `est_duration_s`. Without routes, sources spread round-robin
/// across chassis and the destination is the anonymous "fleet" — use
/// [`plan_migration_routed`] when the caller knows the group placement.
pub fn plan_migration(
    current: &RoleMap,
    target: &RoleMap,
    kv_per_drained_pipeline: f64,
    fabric: &Fabric,
) -> MigrationPlan {
    plan_migration_routed(
        current,
        target,
        kv_per_drained_pipeline,
        fabric,
        &BTreeMap::new(),
    )
}

/// [`plan_migration`] with per-device KV routes: `routes[device]` names
/// the chassis pair and destination group for the KV leaving that
/// drained decode device — the cross-group move the orchestrator's
/// group-granular retarget produces. Devices without a route fall back
/// to the round-robin spread.
pub fn plan_migration_routed(
    current: &RoleMap,
    target: &RoleMap,
    kv_per_drained_pipeline: f64,
    fabric: &Fabric,
    routes: &BTreeMap<String, KvRoute>,
) -> MigrationPlan {
    let mut steps = Vec::new();
    let mut kv_bytes = 0.0;
    // (device, drained count) per shrinking decode entry, in map order.
    let mut drained: Vec<(String, u32)> = Vec::new();

    // 1. Activations first (make-before-break).
    for ((device, role), want) in target {
        let have = current.get(&(device.clone(), role.clone())).copied().unwrap_or(0);
        if *want > have {
            steps.push(MigrationStep::Activate {
                device: device.clone(),
                role: role.clone(),
                count: want - have,
            });
        }
    }
    // 2. KV transfers out of shrinking decode pipelines.
    for ((device, role), have) in current {
        let want = target.get(&(device.clone(), role.clone())).copied().unwrap_or(0);
        if *have > want && role == "decode" {
            let n = have - want;
            let moved = n as f64 * kv_per_drained_pipeline;
            kv_bytes += moved;
            drained.push((device.clone(), n));
            steps.push(MigrationStep::TransferKv {
                bytes: moved,
                from: device.clone(),
                to: routes
                    .get(device)
                    .map(|r| r.to_label.clone())
                    .unwrap_or_else(|| "fleet".into()),
            });
        }
    }
    // 3. Drains last.
    for ((device, role), have) in current {
        let want = target.get(&(device.clone(), role.clone())).copied().unwrap_or(0);
        if *have > want {
            steps.push(MigrationStep::Drain {
                device: device.clone(),
                role: role.clone(),
                count: have - want,
            });
        }
    }

    // Price the KV motion on a private contended clock — the same FIFO
    // reservation model both execution backends charge hops on. No
    // reservation side effects leak to the caller.
    let mut clock = TransferClock::new(fabric.clone());
    clock.reset();
    let max_route_chassis = routes
        .values()
        .map(|r| r.from_chassis.max(r.to_chassis) + 1)
        .max()
        .unwrap_or(0);
    clock.grow(max_route_chassis);
    let n_chassis = clock.n_chassis().max(1);
    let mut done = 0.0f64;
    let mut i = 0u32;
    for (device, n) in &drained {
        for j in 0..*n {
            let (from, to) = match routes.get(device) {
                // The j-th drained replica leaves from one chassis
                // below the previous (top-down retirement), so the
                // transfers contend only where replicas truly share a
                // NIC.
                Some(r) => (r.from_chassis.saturating_sub(j), r.to_chassis),
                None => (i % n_chassis, (i + 1) % n_chassis),
            };
            if let Ok(t) = clock.transfer(from, to, kv_per_drained_pipeline, 0.0) {
                done = done.max(t);
            }
            i += 1;
        }
    }

    MigrationPlan {
        steps,
        kv_bytes,
        est_duration_s: done + MIGRATION_OVERHEAD_S,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn role_map(entries: &[(&str, &str, u32)]) -> RoleMap {
        entries
            .iter()
            .map(|(d, r, n)| ((d.to_string(), r.to_string()), *n))
            .collect()
    }

    fn fabric() -> Fabric {
        // 4 chassis, 900 GB/s scale-up, 400 Gbit RoCE NICs.
        Fabric::new(4, 8, 900.0, 400.0)
    }

    #[test]
    fn activation_before_drain() {
        let cur = role_map(&[("H100", "decode", 2)]);
        let tgt = role_map(&[("Gaudi3", "decode", 2)]);
        let plan = plan_migration(&cur, &tgt, 1e9, &fabric());
        let first_activate = plan
            .steps
            .iter()
            .position(|s| matches!(s, MigrationStep::Activate { .. }))
            .unwrap();
        let first_drain = plan
            .steps
            .iter()
            .position(|s| matches!(s, MigrationStep::Drain { .. }))
            .unwrap();
        assert!(first_activate < first_drain);
        assert_eq!(plan.kv_bytes, 2e9);
        assert!(plan.est_duration_s > MIGRATION_OVERHEAD_S);
    }

    #[test]
    fn no_change_no_steps() {
        let cur = role_map(&[("H100", "prefill", 1), ("Gaudi3", "decode", 2)]);
        let plan = plan_migration(&cur, &cur, 1e9, &fabric());
        assert!(plan.steps.is_empty());
        assert_eq!(plan.kv_bytes, 0.0);
        assert_eq!(plan.est_duration_s, MIGRATION_OVERHEAD_S);
    }

    #[test]
    fn partial_shrink_moves_partial_kv() {
        let cur = role_map(&[("Gaudi3", "decode", 4)]);
        let tgt = role_map(&[("Gaudi3", "decode", 3)]);
        let plan = plan_migration(&cur, &tgt, 5e8, &fabric());
        assert_eq!(plan.kv_bytes, 5e8);
        assert!(plan
            .steps
            .iter()
            .any(|s| matches!(s, MigrationStep::Drain { count: 1, .. })));
    }

    #[test]
    fn prefill_drain_moves_no_kv() {
        let cur = role_map(&[("H100", "prefill", 2)]);
        let tgt = role_map(&[("H100", "prefill", 1)]);
        let plan = plan_migration(&cur, &tgt, 1e9, &fabric());
        assert_eq!(plan.kv_bytes, 0.0);
        assert_eq!(plan.est_duration_s, MIGRATION_OVERHEAD_S);
    }

    #[test]
    fn duration_follows_fabric_bandwidth_and_contention() {
        // 1 GB per drained pipeline over a 400 Gbit (50 GB/s) NIC path:
        // two NIC hops ≈ 40 ms per transfer when uncontended.
        let cur = role_map(&[("Gaudi3", "decode", 2)]);
        let tgt = role_map(&[("Gaudi3", "decode", 1)]);
        let one = plan_migration(&cur, &tgt, 1e9, &fabric());
        let xfer_one = one.est_duration_s - MIGRATION_OVERHEAD_S;
        assert!(xfer_one > 0.02 && xfer_one < 0.2, "xfer={xfer_one}");

        // A fatter NIC moves the same KV faster.
        let fat = Fabric::new(4, 8, 900.0, 1600.0);
        let fast = plan_migration(&cur, &tgt, 1e9, &fat);
        assert!(fast.est_duration_s < one.est_duration_s);

        // Many drains on a tiny fabric contend for the same NICs: the
        // aggregate slows down vs a single drain of the same per-pipe KV.
        let tiny = Fabric::new(2, 8, 900.0, 400.0);
        let cur8 = role_map(&[("Gaudi3", "decode", 8)]);
        let tgt0 = role_map(&[("Gaudi3", "decode", 1)]);
        let many = plan_migration(&cur8, &tgt0, 1e9, &tiny);
        let single = plan_migration(&cur, &tgt, 1e9, &tiny);
        assert!(
            many.est_duration_s > single.est_duration_s,
            "contention must slow the fleet-wide drain: {} vs {}",
            many.est_duration_s,
            single.est_duration_s
        );
    }

    #[test]
    fn routed_kv_names_the_absorbing_group_and_prices_the_real_hop() {
        let cur = role_map(&[("A100", "decode", 2), ("H100", "decode", 1)]);
        let tgt = role_map(&[("A100", "decode", 1), ("H100", "decode", 2)]);
        let mut routes = BTreeMap::new();
        routes.insert(
            "A100".to_string(),
            KvRoute {
                from_chassis: 3,
                to_chassis: 1,
                to_label: "decode H100 tp1 pp1 b16".to_string(),
            },
        );
        let routed = plan_migration_routed(&cur, &tgt, 1e9, &fabric(), &routes);
        // The transfer step names the surviving group, not "fleet".
        assert!(routed.steps.iter().any(|s| matches!(
            s,
            MigrationStep::TransferKv { to, from, .. }
                if to == "decode H100 tp1 pp1 b16" && from == "A100"
        )));
        assert_eq!(routed.kv_bytes, 1e9);
        // Same-chassis route ⇒ scale-up hop ⇒ cheaper than the NIC path.
        let mut local = BTreeMap::new();
        local.insert(
            "A100".to_string(),
            KvRoute {
                from_chassis: 1,
                to_chassis: 1,
                to_label: "x".into(),
            },
        );
        let free = plan_migration_routed(&cur, &tgt, 1e9, &fabric(), &local);
        assert!(free.est_duration_s <= routed.est_duration_s);
        assert!((free.est_duration_s - MIGRATION_OVERHEAD_S).abs() < 1e-9);
        // Routes outside the fabric grow it rather than erroring.
        let mut far = BTreeMap::new();
        far.insert(
            "A100".to_string(),
            KvRoute {
                from_chassis: 9,
                to_chassis: 0,
                to_label: "x".into(),
            },
        );
        let grown = plan_migration_routed(&cur, &tgt, 1e9, &fabric(), &far);
        assert!(grown.est_duration_s > MIGRATION_OVERHEAD_S);
        // Unrouted devices keep the round-robin fallback (legacy path).
        let plain = plan_migration(&cur, &tgt, 1e9, &fabric());
        assert!(plain
            .steps
            .iter()
            .any(|s| matches!(s, MigrationStep::TransferKv { to, .. } if to == "fleet")));
    }

    #[test]
    fn role_map_lowering_and_json_round_trip() {
        let plan = crate::plan::tests::tiny_plan();
        let m = role_map_of(&plan);
        assert_eq!(m[&("H100".to_string(), "prefill".to_string())], 1);
        assert_eq!(m[&("Gaudi3".to_string(), "decode".to_string())], 2);
        assert_eq!(role_replicas(&plan, Role::Prefill), 1);
        assert_eq!(role_replicas(&plan, Role::Decode), 2);

        let cur = role_map(&[("H100", "decode", 2), ("H100", "prefill", 1)]);
        let tgt = role_map(&[("Gaudi3", "decode", 3), ("H100", "prefill", 1)]);
        let mp = plan_migration(&cur, &tgt, 2e9, &fabric());
        let back =
            MigrationPlan::from_json(&Json::parse(&mp.to_json().pretty()).unwrap()).unwrap();
        assert_eq!(back, mp);
    }
}
