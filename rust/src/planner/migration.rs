//! Migration planning: when the optimizer's placement moves, produce a
//! safe drain → transfer → activate step sequence (§4.1 "workload
//! migration"). Steps are ordered so capacity never goes negative:
//! activations precede the drains they replace.

/// One migration action.
#[derive(Debug, Clone, PartialEq)]
pub enum MigrationStep {
    /// Bring up a pipeline of `count` devices of `device` for `role`.
    Activate {
        device: String,
        role: String,
        count: u32,
    },
    /// Move a session's KV bytes between nodes.
    TransferKv { bytes: f64, from: String, to: String },
    /// Stop routing to, then tear down, a pipeline.
    Drain {
        device: String,
        role: String,
        count: u32,
    },
}

/// A role's worth of capacity (device name → pipeline count).
pub type RoleMap = std::collections::BTreeMap<(String, String), u32>;

/// A full migration plan with a cost estimate.
#[derive(Debug, Clone)]
pub struct MigrationPlan {
    pub steps: Vec<MigrationStep>,
    /// KV bytes that must move.
    pub kv_bytes: f64,
    /// Estimated wall time to complete, seconds.
    pub est_duration_s: f64,
}

/// Diff two fleet layouts into an ordered step list.
///
/// `kv_per_drained_pipeline` prices the state that must leave each
/// drained decode pipeline (prefill pipelines are stateless).
pub fn plan_migration(
    current: &RoleMap,
    target: &RoleMap,
    kv_per_drained_pipeline: f64,
    link_bytes_per_s: f64,
) -> MigrationPlan {
    let mut steps = Vec::new();
    let mut kv_bytes = 0.0;

    // 1. Activations first (make-before-break).
    for ((device, role), want) in target {
        let have = current.get(&(device.clone(), role.clone())).copied().unwrap_or(0);
        if *want > have {
            steps.push(MigrationStep::Activate {
                device: device.clone(),
                role: role.clone(),
                count: want - have,
            });
        }
    }
    // 2. KV transfers out of shrinking decode pipelines.
    for ((device, role), have) in current {
        let want = target.get(&(device.clone(), role.clone())).copied().unwrap_or(0);
        if *have > want && role == "decode" {
            let moved = (have - want) as f64 * kv_per_drained_pipeline;
            kv_bytes += moved;
            steps.push(MigrationStep::TransferKv {
                bytes: moved,
                from: device.clone(),
                to: "fleet".into(),
            });
        }
    }
    // 3. Drains last.
    for ((device, role), have) in current {
        let want = target.get(&(device.clone(), role.clone())).copied().unwrap_or(0);
        if *have > want {
            steps.push(MigrationStep::Drain {
                device: device.clone(),
                role: role.clone(),
                count: have - want,
            });
        }
    }

    MigrationPlan {
        steps,
        kv_bytes,
        est_duration_s: kv_bytes / link_bytes_per_s + 1.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn role_map(entries: &[(&str, &str, u32)]) -> RoleMap {
        entries
            .iter()
            .map(|(d, r, n)| ((d.to_string(), r.to_string()), *n))
            .collect()
    }

    #[test]
    fn activation_before_drain() {
        let cur = role_map(&[("H100", "decode", 2)]);
        let tgt = role_map(&[("Gaudi3", "decode", 2)]);
        let plan = plan_migration(&cur, &tgt, 1e9, 50e9);
        let first_activate = plan
            .steps
            .iter()
            .position(|s| matches!(s, MigrationStep::Activate { .. }))
            .unwrap();
        let first_drain = plan
            .steps
            .iter()
            .position(|s| matches!(s, MigrationStep::Drain { .. }))
            .unwrap();
        assert!(first_activate < first_drain);
        assert_eq!(plan.kv_bytes, 2e9);
        assert!(plan.est_duration_s > 1.0);
    }

    #[test]
    fn no_change_no_steps() {
        let cur = role_map(&[("H100", "prefill", 1), ("Gaudi3", "decode", 2)]);
        let plan = plan_migration(&cur, &cur, 1e9, 50e9);
        assert!(plan.steps.is_empty());
        assert_eq!(plan.kv_bytes, 0.0);
    }

    #[test]
    fn partial_shrink_moves_partial_kv() {
        let cur = role_map(&[("Gaudi3", "decode", 4)]);
        let tgt = role_map(&[("Gaudi3", "decode", 3)]);
        let plan = plan_migration(&cur, &tgt, 5e8, 50e9);
        assert_eq!(plan.kv_bytes, 5e8);
        assert!(plan
            .steps
            .iter()
            .any(|s| matches!(s, MigrationStep::Drain { count: 1, .. })));
    }

    #[test]
    fn prefill_drain_moves_no_kv() {
        let cur = role_map(&[("H100", "prefill", 2)]);
        let tgt = role_map(&[("H100", "prefill", 1)]);
        let plan = plan_migration(&cur, &tgt, 1e9, 50e9);
        assert_eq!(plan.kv_bytes, 0.0);
    }
}
