//! Slow-path planner & scheduler (paper §4.1).
//!
//! "Continuously monitors hardware resources and workloads, dynamically
//! allocating tasks based on the optimization strategies outlined in
//! Section 3.1. This component handles workload migration, resource
//! allocation, and planning."
//!
//! * [`plan`] — graph planning: run the IR pipeline, extract θ vectors,
//!   build the §3.1.2 assignment problem over the device catalog (plus
//!   a CPU class), solve it, and lower the result into a serializable
//!   [`crate::plan::ExecutionPlan`] consumed by the simulator and the
//!   server alike;
//! * [`migration`] — drain/transfer/activate step generation when the
//!   optimum moves;
//! * [`autoscale`] — utilization-driven pipeline scaling with
//!   hysteresis;
//! * [`feedback`] — EWMA profile updates from observed latencies
//!   (Figure 6's "runtime resource feedback" arrow).

pub mod autoscale;
pub mod edge;
pub mod feedback;
pub mod migration;
pub mod plan;

pub use autoscale::{
    score_groups, Autoscaler, AutoscalerConfig, GroupFired, GroupScaler, GroupScore,
    ScaleDecision,
};
pub use feedback::ProfileStore;
pub use migration::{
    plan_migration, plan_migration_routed, role_map_of, role_replicas, KvRoute, MigrationPlan,
    MigrationStep, RoleMap,
};
pub use plan::{Planner, PlannerConfig};
