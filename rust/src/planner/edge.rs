//! Cross-device (cloud ↔ edge) agent planning — paper §7.2.
//!
//! "Recent protocols like Minion and MinionS demonstrate practical
//! benefits of decomposing and parallelizing tasks between local and
//! cloud language models, significantly reducing costs while preserving
//! accuracy. Formalizing and generalizing these approaches into
//! comprehensive optimization frameworks..." — this module is that
//! formalization at the fidelity of the rest of the cost model: a task
//! mix of decomposable subtasks, a local (edge) small model priced by
//! energy, a cloud endpoint priced per token with RTT, and an optimizer
//! sweeping the local/cloud split subject to a quality floor.

use crate::cost::model_profile::ModelProfile;
use crate::cost::roofline::{decode_step_time, prefill_time, Efficiency, Parallelism};
use crate::cost::hardware::DeviceSpec;

/// The edge device running the local small model.
#[derive(Debug, Clone)]
pub struct EdgeDevice {
    pub name: String,
    /// Treated as a (weak) DeviceSpec for the roofline.
    pub spec: DeviceSpec,
    /// Marginal energy cost of compute, $/hr at full tilt.
    pub energy_usd_hr: f64,
}

/// A metered cloud endpoint serving the big model.
#[derive(Debug, Clone)]
pub struct CloudEndpoint {
    pub model_name: String,
    pub usd_per_mtok_in: f64,
    pub usd_per_mtok_out: f64,
    /// Round-trip network latency per call, seconds.
    pub rtt_s: f64,
}

/// A decomposable agent job (the MinionS shape): `n_subtasks` pieces,
/// of which `easy_fraction` are solvable by the local model at full
/// quality; hard pieces need the cloud model.
#[derive(Debug, Clone)]
pub struct TaskMix {
    pub n_subtasks: u32,
    pub easy_fraction: f64,
    /// Tokens per subtask.
    pub isl: u64,
    pub osl: u64,
    /// Supervision overhead: cloud tokens spent aggregating local
    /// results (per local subtask).
    pub supervision_tokens: u64,
}

/// Where a fraction of subtasks runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Strategy {
    AllCloud,
    AllLocal,
    /// Send this fraction of subtasks to the local model (≤ easy
    /// fraction to preserve quality), rest + supervision to the cloud.
    Split { local_fraction: f64 },
}

/// Evaluated plan.
#[derive(Debug, Clone)]
pub struct EdgePlan {
    pub strategy: Strategy,
    pub cost_usd: f64,
    /// Wall time with local subtasks run sequentially on the edge device
    /// and cloud calls pipelined (one RTT per wave).
    pub latency_s: f64,
    /// Fraction of subtasks answered at full quality.
    pub quality: f64,
}

/// The cloud-edge optimizer.
pub struct EdgePlanner {
    pub edge: EdgeDevice,
    pub local_model: ModelProfile,
    pub cloud: CloudEndpoint,
    pub eff: Efficiency,
}

impl EdgePlanner {
    /// Time for the local model to finish one subtask on the edge device.
    pub fn local_subtask_s(&self, mix: &TaskMix) -> f64 {
        let par = Parallelism { tp: 1, pp: 1 };
        let pre = prefill_time(&self.local_model, &self.edge.spec, par, mix.isl, 1, &self.eff)
            .total();
        let step = decode_step_time(
            &self.local_model,
            &self.edge.spec,
            par,
            mix.isl + mix.osl / 2,
            1,
            &self.eff,
        )
        .total();
        pre + step * mix.osl as f64
    }

    /// Cloud cost/latency for one subtask.
    fn cloud_subtask(&self, isl: u64, osl: u64) -> (f64, f64) {
        let cost = isl as f64 / 1e6 * self.cloud.usd_per_mtok_in
            + osl as f64 / 1e6 * self.cloud.usd_per_mtok_out;
        // Latency: RTT + a serving-side budget (interactive SLA rates).
        let latency = self.cloud.rtt_s + 0.25 + 0.02 * osl as f64;
        (cost, latency)
    }

    /// Evaluate a strategy on a mix.
    pub fn evaluate(&self, strategy: Strategy, mix: &TaskMix) -> EdgePlan {
        let n = mix.n_subtasks as f64;
        match strategy {
            Strategy::AllCloud => {
                let (c, l) = self.cloud_subtask(mix.isl, mix.osl);
                EdgePlan {
                    strategy,
                    cost_usd: c * n,
                    // Cloud calls fan out in parallel: one wave.
                    latency_s: l,
                    quality: 1.0,
                }
            }
            Strategy::AllLocal => {
                let t = self.local_subtask_s(mix) * n;
                EdgePlan {
                    strategy,
                    cost_usd: t / 3600.0 * self.edge.energy_usd_hr,
                    latency_s: t,
                    // Hard subtasks degrade when forced local.
                    quality: mix.easy_fraction,
                }
            }
            Strategy::Split { local_fraction } => {
                let f = local_fraction.clamp(0.0, 1.0);
                let n_local = n * f;
                let n_cloud = n - n_local;
                let t_local = self.local_subtask_s(mix) * n_local;
                let cost_local = t_local / 3600.0 * self.edge.energy_usd_hr;
                let (c_cloud, l_cloud) = self.cloud_subtask(mix.isl, mix.osl);
                // Supervision: the cloud model reads local results.
                let (c_sup, l_sup) =
                    self.cloud_subtask(mix.supervision_tokens * n_local as u64, 64);
                let quality = if f <= mix.easy_fraction {
                    1.0
                } else {
                    1.0 - (f - mix.easy_fraction)
                };
                EdgePlan {
                    strategy,
                    cost_usd: cost_local + c_cloud * n_cloud + c_sup,
                    latency_s: (t_local + l_sup).max(if n_cloud > 0.0 { l_cloud } else { 0.0 }),
                    quality,
                }
            }
        }
    }

    /// Sweep local fractions; return the cheapest plan meeting the
    /// quality floor and latency bound.
    pub fn best_plan(
        &self,
        mix: &TaskMix,
        quality_floor: f64,
        latency_bound_s: f64,
    ) -> Option<EdgePlan> {
        let mut candidates = vec![
            self.evaluate(Strategy::AllCloud, mix),
            self.evaluate(Strategy::AllLocal, mix),
        ];
        for k in 1..=20 {
            let f = k as f64 / 20.0;
            candidates.push(self.evaluate(Strategy::Split { local_fraction: f }, mix));
        }
        candidates
            .into_iter()
            .filter(|p| p.quality >= quality_floor && p.latency_s <= latency_bound_s)
            .min_by(|a, b| a.cost_usd.partial_cmp(&b.cost_usd).unwrap())
    }
}

/// A reasonable default edge device: a workstation-class GPU (A40-like
/// but slower memory + low energy price).
pub fn default_edge() -> EdgeDevice {
    let mut spec = crate::cost::hardware::by_name("A40").unwrap();
    spec.name = "EdgeGPU";
    EdgeDevice {
        name: "workstation".into(),
        spec,
        energy_usd_hr: 0.12, // 300 W @ $0.40/kWh
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::model_profile::{llama3_70b, llama3_8b};
    use crate::cost::Precision;

    fn planner() -> EdgePlanner {
        EdgePlanner {
            edge: default_edge(),
            local_model: llama3_8b(Precision::Fp8),
            cloud: CloudEndpoint {
                model_name: llama3_70b(Precision::Fp8).name.to_string(),
                usd_per_mtok_in: 0.6,
                usd_per_mtok_out: 2.4,
                rtt_s: 0.08,
            },
            eff: Efficiency::default(),
        }
    }

    fn mix() -> TaskMix {
        TaskMix {
            n_subtasks: 20,
            easy_fraction: 0.7,
            isl: 2048,
            osl: 128,
            supervision_tokens: 128,
        }
    }

    #[test]
    fn split_cuts_cost_vs_all_cloud_at_full_quality() {
        // The MinionS headline: decompose + run easy pieces locally =>
        // large cost reduction with no quality loss.
        let p = planner();
        let all_cloud = p.evaluate(Strategy::AllCloud, &mix());
        let best = p.best_plan(&mix(), 1.0, f64::INFINITY).unwrap();
        assert!(best.quality >= 1.0 - 1e-9);
        assert!(
            best.cost_usd < 0.7 * all_cloud.cost_usd,
            "split ${} should be well under cloud ${}",
            best.cost_usd,
            all_cloud.cost_usd
        );
        match best.strategy {
            Strategy::Split { local_fraction } => {
                assert!(local_fraction > 0.0 && local_fraction <= 0.7 + 1e-9);
            }
            Strategy::AllCloud => panic!("expected a split"),
            Strategy::AllLocal => panic!("all-local can't hit quality 1.0"),
        }
    }

    #[test]
    fn all_local_fails_quality_floor() {
        let p = planner();
        let plan = p.evaluate(Strategy::AllLocal, &mix());
        assert!(plan.quality < 1.0);
        assert!(plan.cost_usd < p.evaluate(Strategy::AllCloud, &mix()).cost_usd);
    }

    #[test]
    fn tight_latency_pushes_back_to_cloud() {
        // Sequential local execution is slow; a tight latency bound must
        // shrink the local fraction (or go all-cloud).
        let p = planner();
        let loose = p.best_plan(&mix(), 1.0, f64::INFINITY).unwrap();
        let tight = p.best_plan(&mix(), 1.0, 3.0).unwrap();
        let frac = |s: &Strategy| match s {
            Strategy::Split { local_fraction } => *local_fraction,
            Strategy::AllLocal => 1.0,
            Strategy::AllCloud => 0.0,
        };
        assert!(frac(&tight.strategy) <= frac(&loose.strategy));
        assert!(tight.latency_s <= 3.0);
    }

    #[test]
    fn infeasible_constraints_return_none() {
        let p = planner();
        assert!(p.best_plan(&mix(), 1.1, f64::INFINITY).is_none());
        assert!(p.best_plan(&mix(), 1.0, 1e-6).is_none());
    }

    #[test]
    fn quality_degrades_past_easy_fraction() {
        let p = planner();
        let q = |f: f64| {
            p.evaluate(Strategy::Split { local_fraction: f }, &mix()).quality
        };
        assert_eq!(q(0.5), 1.0);
        assert_eq!(q(0.7), 1.0);
        assert!(q(0.9) < 1.0);
        assert!(q(1.0) < q(0.9) + 1e-9);
    }

    #[test]
    fn cheaper_cloud_shifts_the_split() {
        // If cloud tokens get 10x cheaper, the optimal local fraction
        // shouldn't grow.
        let p = planner();
        let mut cheap = planner();
        cheap.cloud.usd_per_mtok_in /= 10.0;
        cheap.cloud.usd_per_mtok_out /= 10.0;
        let f = |pl: &EdgePlanner| match pl.best_plan(&mix(), 1.0, f64::INFINITY).unwrap().strategy {
            Strategy::Split { local_fraction } => local_fraction,
            Strategy::AllCloud => 0.0,
            Strategy::AllLocal => 1.0,
        };
        assert!(f(&cheap) <= f(&p) + 1e-9);
    }
}
