//! Graph planning: annotated IR → assignment problem → placement.
//!
//! This is where the three pillars meet: the IR pipeline decomposes and
//! annotates the agent graph (§4.2), the cost model prices each node on
//! each hardware class (§3.1.1), and the optimizer picks the cheapest
//! SLA-feasible assignment (§3.1.2). §5.3's observed behaviour — "our
//! optimization framework places the non-LLM components of the voice
//! agent on CPUs ... prefill and decode allocations are quite distinct"
//! — falls out of exactly this pipeline (asserted in tests).

use crate::cost::hardware::{catalog, DeviceSpec};
use crate::cost::model_profile::by_short_name;
use crate::cost::roofline::{
    decode_step_time, prefill_time, Efficiency, Parallelism,
};
use crate::cost::tco::{opex_usd_per_hour, FinanceTerms, OpexModel};
use crate::ir::graph::Graph;
use crate::ir::passes::PassManager;
use crate::opt::assignment::{
    Assignment, AssignmentProblem, EdgeSpec, HardwareClass, Sla, TaskSpec,
};
use crate::{Error, Result};

/// Planner configuration.
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    pub eff: Efficiency,
    pub opex: OpexModel,
    pub terms: FinanceTerms,
    /// End-to-end SLA for the whole agent graph, seconds.
    pub sla: Sla,
    /// CPU-node pseudo-class hourly cost (a 64-core server share).
    pub cpu_usd_hr: f64,
    /// Communication-penalty weight γ (per transferred byte, $).
    pub gamma_usd_per_byte: f64,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            eff: Efficiency::default(),
            opex: OpexModel::Derived,
            terms: FinanceTerms::default(),
            sla: Sla::EndToEnd(5.0),
            cpu_usd_hr: 0.08,
            gamma_usd_per_byte: 4e-12, // ~ $0.004/GB moved
        }
    }
}

/// The outcome: per-node class choice with names resolved.
#[derive(Debug, Clone)]
pub struct GraphPlan {
    /// (node op, chosen class name).
    pub placements: Vec<(String, String)>,
    pub cost_usd: f64,
    pub latency_s: f64,
    pub assignment: Assignment,
    /// Pass log from the lowering pipeline.
    pub pass_log: Vec<(String, bool)>,
}

impl GraphPlan {
    /// Which class a given op landed on (first occurrence).
    pub fn class_of(&self, op: &str) -> Option<&str> {
        self.placements
            .iter()
            .find(|(o, _)| o == op)
            .map(|(_, c)| c.as_str())
    }
}

/// The slow-path planner.
pub struct Planner {
    pub cfg: PlannerConfig,
    devices: Vec<DeviceSpec>,
}

/// Baseline CPU timings for non-accelerator task classes, seconds.
/// ("profiled from system traces, benchmarks, or prior executions" —
/// these are the defaults; [`crate::planner::feedback`] refines them.)
fn cpu_latency_s(op: &str) -> f64 {
    match op {
        "stt.transcribe" => 0.35,
        "tts.synthesize" => 0.20,
        "tool.lookup" => 0.30, // network-dominated
        "tool.compute" => 0.01,
        "tool.call" => 0.31,
        "gp.compute" => 0.005,
        "ctrl.plan" | "ctrl.branch" | "ctrl.merge" => 0.001,
        "mem.lookup" => 0.02,
        "mem.store" | "obs.store" => 0.005,
        "kv.read" | "kv.write" => 0.002,
        "gate.select" | "moe.merge" => 0.002,
        "io.input" | "io.output" => 0.0005,
        _ => 0.01,
    }
}

impl Planner {
    pub fn new(cfg: PlannerConfig) -> Planner {
        Planner {
            cfg,
            devices: catalog(),
        }
    }

    /// Restrict the device catalog (e.g. what the fleet actually has).
    pub fn with_devices(mut self, devices: Vec<DeviceSpec>) -> Planner {
        self.devices = devices;
        self
    }

    /// Hardware classes: every accelerator + the CPU pseudo-class (last).
    pub fn classes(&self) -> Vec<HardwareClass> {
        let mut out: Vec<HardwareClass> = self
            .devices
            .iter()
            .map(|d| HardwareClass {
                name: d.name.to_string(),
                capacity: 0.0,
            })
            .collect();
        out.push(HardwareClass {
            name: "CPU".to_string(),
            capacity: 0.0,
        });
        out
    }

    fn opex(&self, class_idx: usize) -> f64 {
        if class_idx == self.devices.len() {
            self.cfg.cpu_usd_hr
        } else {
            opex_usd_per_hour(&self.devices[class_idx], self.cfg.opex, &self.cfg.terms)
        }
    }

    /// Latency of an IR node on a hardware class.
    fn latency(&self, node: &crate::ir::graph::Node, class_idx: usize) -> f64 {
        let is_cpu = class_idx == self.devices.len();
        let base = cpu_latency_s(&node.op);
        match node.op.as_str() {
            "llm.prefill" | "moe.expert_prefill" => {
                if is_cpu {
                    return f64::INFINITY; // not placeable
                }
                let d = &self.devices[class_idx];
                let model = node.attr_str("model").and_then(by_short_name);
                match model {
                    Some(m) => {
                        let isl = node.attr_int("isl").map(|v| v as u64).unwrap_or(512);
                        let frac = node.attr_f64("token_fraction").unwrap_or(1.0);
                        let par = Parallelism { tp: 1, pp: 1 };
                        prefill_time(&m, d, par, ((isl as f64 * frac) as u64).max(1), 1, &self.cfg.eff)
                            .total()
                    }
                    None => 0.05,
                }
            }
            "llm.decode" | "moe.expert_decode" => {
                if is_cpu {
                    return f64::INFINITY;
                }
                let d = &self.devices[class_idx];
                let model = node.attr_str("model").and_then(by_short_name);
                match model {
                    Some(m) => {
                        let isl = node.attr_int("isl").map(|v| v as u64).unwrap_or(512);
                        let osl = node.attr_int("osl").map(|v| v as u64).unwrap_or(128);
                        let par = Parallelism { tp: 1, pp: 1 };
                        let step =
                            decode_step_time(&m, d, par, isl + osl / 2, 1, &self.cfg.eff)
                                .total();
                        step * osl as f64
                    }
                    None => 0.5,
                }
            }
            "llm.infer" | "llm.diffuse" => {
                if is_cpu {
                    f64::INFINITY
                } else {
                    // Whole-model op (pre-decomposition): coarse estimate.
                    0.5 * 1979.0 / self.devices[class_idx].tflops_fp16
                }
            }
            // CPU-friendly ops: same wall time on CPU; accelerators
            // don't speed up network- or logic-bound work.
            _ => base,
        }
    }

    /// Build the assignment problem from an *annotated* graph.
    pub fn build_problem(&self, g: &Graph) -> Result<AssignmentProblem> {
        let classes = self.classes();
        let n_classes = classes.len();
        let cpu_idx = n_classes - 1;

        let mut tasks = Vec::new();
        let mut value_to_task: std::collections::BTreeMap<u32, usize> =
            std::collections::BTreeMap::new();

        for node in &g.nodes {
            let mut latency_s = Vec::with_capacity(n_classes);
            let mut cost_usd = Vec::with_capacity(n_classes);
            let mut forbidden = Vec::new();
            let wants_accel = node
                .attr("wants_accel")
                .and_then(|a| a.as_bool())
                .unwrap_or(false);
            for j in 0..n_classes {
                let t = self.latency(node, j);
                if t.is_infinite() {
                    forbidden.push(j);
                    latency_s.push(1e9);
                    cost_usd.push(1e9);
                } else {
                    latency_s.push(t);
                    cost_usd.push(t * self.opex(j) / 3600.0);
                }
            }
            // Accelerator-hungry nodes must not land on CPU.
            if wants_accel && !forbidden.contains(&cpu_idx) {
                forbidden.push(cpu_idx);
            }
            let idx = tasks.len();
            for r in &node.results {
                value_to_task.insert(r.0, idx);
            }
            tasks.push(TaskSpec {
                name: format!("{}#{}", node.op, node.id.0),
                latency_s,
                cost_usd,
                capacity_use: 0.0,
                forbidden,
            });
        }

        // Edges: dataflow with transfer cost when classes differ,
        // priced by annotated est_bytes on the consumer (kv.transfer).
        let mut edges = Vec::new();
        for (ni, node) in g.nodes.iter().enumerate() {
            for o in &node.operands {
                if let Some(&src) = value_to_task.get(&o.0) {
                    let bytes = node.attr_f64("est_bytes").unwrap_or(1e6);
                    let mut lat = vec![vec![0.0; n_classes]; n_classes];
                    let mut cost = vec![vec![0.0; n_classes]; n_classes];
                    for a in 0..n_classes {
                        for b in 0..n_classes {
                            if a != b {
                                // Cross-class hop over the scale-out NIC.
                                let bw = 50e9 * self.cfg.eff.net_util;
                                lat[a][b] = bytes / bw + 1e-4;
                                cost[a][b] = bytes * self.cfg.gamma_usd_per_byte;
                            }
                        }
                    }
                    edges.push(EdgeSpec {
                        from: src,
                        to: ni,
                        latency_s: lat,
                        cost_usd: cost,
                    });
                }
            }
        }

        Ok(AssignmentProblem {
            classes,
            tasks,
            edges,
            sla: self.cfg.sla,
        })
    }

    /// Full pipeline: lower + annotate the graph, then solve placement.
    pub fn plan(&self, g: &Graph) -> Result<GraphPlan> {
        let mut g = g.clone();
        let mut pm = PassManager::standard();
        pm.run(&mut g)?;
        let problem = self.build_problem(&g)?;
        if problem.tasks.is_empty() {
            return Err(Error::Opt("graph has no tasks".into()));
        }
        // Exact B&B for small graphs; edge-aware local search beyond
        // (inlined hierarchical agents can expose dozens of tasks).
        let assignment = problem.solve_auto()?;
        let placements = g
            .nodes
            .iter()
            .zip(&assignment.choice)
            .map(|(n, &c)| (n.op.clone(), problem.classes[c].name.clone()))
            .collect();
        Ok(GraphPlan {
            placements,
            cost_usd: assignment.cost_usd,
            latency_s: assignment.latency_s,
            assignment,
            pass_log: pm.log.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents;

    fn planner() -> Planner {
        Planner::new(PlannerConfig::default())
    }

    #[test]
    fn voice_agent_non_llm_on_cpu() {
        // §5.3: "Our optimization framework places the non-LLM
        // components of the voice agent on CPUs."
        let g = agents::voice_agent("8b-fp16", 512, 256);
        let plan = planner().plan(&g).unwrap();
        assert_eq!(plan.class_of("stt.transcribe"), Some("CPU"));
        assert_eq!(plan.class_of("tts.synthesize"), Some("CPU"));
        // LLM stages land on accelerators.
        let prefill_class = plan.class_of("llm.prefill").unwrap();
        assert_ne!(prefill_class, "CPU");
        let decode_class = plan.class_of("llm.decode").unwrap();
        assert_ne!(decode_class, "CPU");
    }

    #[test]
    fn prefill_and_decode_classes_can_differ() {
        // The disaggregation headline: with a loose SLA the cheapest
        // prefill device and cheapest decode device are chosen
        // independently (heterogeneous pairing).
        let g = agents::voice_agent("70b-fp8", 4096, 512);
        let mut p = planner();
        p.cfg.sla = Sla::None;
        let plan = p.plan(&g).unwrap();
        let pf = plan.class_of("llm.prefill").unwrap();
        let dc = plan.class_of("llm.decode").unwrap();
        // Not asserting a specific pair (calibration-sensitive), but
        // both must be accelerators and the plan must be finite-cost.
        assert_ne!(pf, "CPU");
        assert_ne!(dc, "CPU");
        assert!(plan.cost_usd < 1.0);
    }

    #[test]
    fn tight_sla_shifts_to_faster_hardware() {
        let g = agents::voice_agent("8b-fp16", 512, 128);
        let mut loose = planner();
        loose.cfg.sla = Sla::None;
        let plan_loose = loose.plan(&g).unwrap();

        // The voice agent's CPU stages (STT/TTS) put a floor on latency,
        // so only a mild tightening is guaranteed feasible.
        let mut tight = planner();
        tight.cfg.sla = Sla::EndToEnd(plan_loose.latency_s * 0.99);
        let plan_tight = tight.plan(&g).unwrap();
        assert!(plan_tight.latency_s <= plan_loose.latency_s);
        assert!(plan_tight.cost_usd >= plan_loose.cost_usd - 1e-12);
    }

    #[test]
    fn impossible_sla_reported_infeasible() {
        let g = agents::voice_agent("8b-fp16", 512, 128);
        let mut p = planner();
        p.cfg.sla = Sla::EndToEnd(1e-6);
        assert!(p.plan(&g).is_err());
    }

    #[test]
    fn pass_log_recorded() {
        let g = agents::voice_agent("8b-fp16", 512, 128);
        let plan = planner().plan(&g).unwrap();
        assert!(plan
            .pass_log
            .iter()
            .any(|(name, changed)| name == "decompose-llm" && *changed));
    }
}
