//! Graph planning: annotated IR → assignment problem → [`ExecutionPlan`].
//!
//! This is where the three pillars meet: the IR pipeline decomposes and
//! annotates the agent graph (§4.2), the cost model prices each node on
//! each hardware class (§3.1.1), and the optimizer picks the cheapest
//! SLA-feasible assignment (§3.1.2). §5.3's observed behaviour — "our
//! optimization framework places the non-LLM components of the voice
//! agent on CPUs ... prefill and decode allocations are quite distinct"
//! — falls out of exactly this pipeline (asserted in tests).
//!
//! The outcome is no longer a loose placement list: [`Planner::plan`]
//! lowers the solved `Assignment` plus the `PlannerConfig` into a
//! serializable [`ExecutionPlan`] — the single artifact the simulator
//! executes ([`crate::cluster::sim::simulate_plan`]) and the server is
//! configured from ([`crate::server::ServerConfig::from_plan`]). The
//! LLM pipeline shapes (TP×PP×batch) come from the §5 configuration
//! explorer ([`crate::opt::parallelism::best_config`]) when the model
//! is in the catalog, unifying the Figure-8/9 machinery with graph
//! planning.

use crate::cost::hardware::{catalog, DeviceSpec};
use crate::cost::model_profile::by_short_name;
use crate::cost::roofline::{
    decode_step_time, prefill_time, Efficiency, Parallelism,
};
use crate::cost::tco::{opex_usd_per_hour, FinanceTerms, OpexModel};
use crate::ir::graph::Graph;
use crate::ir::passes::PassManager;
use crate::opt::assignment::{
    AssignmentProblem, EdgeSpec, HardwareClass, Sla, TaskSpec,
};
use crate::opt::parallelism::{best_config, ExploreOpts, SeqShape, SlaMode};
use crate::plan::{
    AdmissionPolicy, BatchPolicy, ExecutionPlan, FabricSpec, NodeBinding,
    PipelineBinding, Role, Stage,
};
use crate::{Error, Result};

/// Planner configuration.
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    pub eff: Efficiency,
    pub opex: OpexModel,
    pub terms: FinanceTerms,
    /// End-to-end SLA for the whole agent graph, seconds.
    pub sla: Sla,
    /// CPU-node pseudo-class hourly cost (a 64-core server share).
    pub cpu_usd_hr: f64,
    /// Communication-penalty weight γ (per transferred byte, $).
    pub gamma_usd_per_byte: f64,
    /// Prefill pipeline replicas per hardware class in the emitted plan.
    pub prefill_replicas: u32,
    /// Decode pipeline replicas per hardware class in the emitted plan.
    pub decode_replicas: u32,
    /// CPU worker slots for non-LLM stages.
    pub cpu_workers: u32,
    /// Serving-loop batching policy carried into the plan.
    pub batching: BatchPolicy,
    /// Admission policy carried into the plan.
    pub admission: AdmissionPolicy,
    /// Fabric sizing carried into the plan.
    pub fabric: FabricSpec,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            eff: Efficiency::default(),
            opex: OpexModel::Derived,
            terms: FinanceTerms::default(),
            sla: Sla::EndToEnd(5.0),
            cpu_usd_hr: 0.08,
            gamma_usd_per_byte: 4e-12, // ~ $0.004/GB moved
            prefill_replicas: 1,
            decode_replicas: 2,
            cpu_workers: 64,
            batching: BatchPolicy::default(),
            admission: AdmissionPolicy::default(),
            fabric: FabricSpec::default(),
        }
    }
}

/// The slow-path planner.
pub struct Planner {
    pub cfg: PlannerConfig,
    devices: Vec<DeviceSpec>,
}

/// Baseline CPU timings for non-accelerator task classes, seconds.
/// ("profiled from system traces, benchmarks, or prior executions" —
/// these are the defaults; [`crate::planner::feedback`] refines them.)
fn cpu_latency_s(op: &str) -> f64 {
    match op {
        "stt.transcribe" => 0.35,
        "tts.synthesize" => 0.20,
        "tool.lookup" => 0.30, // network-dominated
        "tool.compute" => 0.01,
        "tool.call" => 0.31,
        "gp.compute" => 0.005,
        "ctrl.plan" | "ctrl.branch" | "ctrl.merge" => 0.001,
        "mem.lookup" => 0.02,
        "mem.store" | "obs.store" => 0.005,
        "kv.read" | "kv.write" => 0.002,
        "gate.select" | "moe.merge" => 0.002,
        "io.input" | "io.output" => 0.0005,
        _ => 0.01,
    }
}

/// Expected prefix-cache overlap for node `idx`: an explicit
/// `prefix_overlap` annotation wins; otherwise a structural rule — a
/// prefill step whose operand list is identical to an *earlier*
/// prefill's re-sends the same context verbatim (fan-out siblings
/// gated on the same planner output), so by the time it dispatches the
/// prefix KV is expected fully resident. Non-prefill ops never reuse.
fn prefix_overlap_of(g: &Graph, idx: usize) -> f64 {
    let node = &g.nodes[idx];
    if !matches!(node.op.as_str(), "llm.prefill" | "moe.expert_prefill") {
        return 0.0;
    }
    if let Some(v) = node.attr_f64("prefix_overlap") {
        return if v.is_finite() { v.clamp(0.0, 1.0) } else { 0.0 };
    }
    let shared = g.nodes[..idx].iter().any(|m| {
        matches!(m.op.as_str(), "llm.prefill" | "moe.expert_prefill")
            && !m.operands.is_empty()
            && m.operands == node.operands
    });
    if shared {
        1.0
    } else {
        0.0
    }
}

impl Planner {
    pub fn new(cfg: PlannerConfig) -> Planner {
        Planner {
            cfg,
            devices: catalog(),
        }
    }

    /// Restrict the device catalog (e.g. what the fleet actually has).
    pub fn with_devices(mut self, devices: Vec<DeviceSpec>) -> Planner {
        self.devices = devices;
        self
    }

    /// Hardware classes: every accelerator + the CPU pseudo-class (last).
    pub fn classes(&self) -> Vec<HardwareClass> {
        let mut out: Vec<HardwareClass> = self
            .devices
            .iter()
            .map(|d| HardwareClass {
                name: d.name.to_string(),
                capacity: 0.0,
            })
            .collect();
        out.push(HardwareClass {
            name: "CPU".to_string(),
            capacity: 0.0,
        });
        out
    }

    fn opex(&self, class_idx: usize) -> f64 {
        if class_idx == self.devices.len() {
            self.cfg.cpu_usd_hr
        } else {
            opex_usd_per_hour(&self.devices[class_idx], self.cfg.opex, &self.cfg.terms)
        }
    }

    /// Latency of an IR node on a hardware class. `prefix_overlap` is
    /// the expected fraction of the prompt already resident in a prefix
    /// cache ([`prefix_overlap_of`]); only the prefill term is
    /// discounted by it — compute scales with *uncached* tokens.
    fn latency(
        &self,
        node: &crate::ir::graph::Node,
        class_idx: usize,
        prefix_overlap: f64,
    ) -> f64 {
        let is_cpu = class_idx == self.devices.len();
        let base = cpu_latency_s(&node.op);
        match node.op.as_str() {
            "llm.prefill" | "moe.expert_prefill" => {
                if is_cpu {
                    return f64::INFINITY; // not placeable
                }
                let d = &self.devices[class_idx];
                let model = node.attr_str("model").and_then(by_short_name);
                match model {
                    Some(m) => {
                        let isl = node.attr_int("isl").map(|v| v as u64).unwrap_or(512);
                        let frac = node.attr_f64("token_fraction").unwrap_or(1.0);
                        let uncached = frac * (1.0 - prefix_overlap.clamp(0.0, 1.0));
                        let par = Parallelism { tp: 1, pp: 1 };
                        prefill_time(
                            &m,
                            d,
                            par,
                            ((isl as f64 * uncached) as u64).max(1),
                            1,
                            &self.cfg.eff,
                        )
                        .total()
                    }
                    None => 0.05,
                }
            }
            "llm.decode" | "moe.expert_decode" => {
                if is_cpu {
                    return f64::INFINITY;
                }
                let d = &self.devices[class_idx];
                let model = node.attr_str("model").and_then(by_short_name);
                match model {
                    Some(m) => {
                        let isl = node.attr_int("isl").map(|v| v as u64).unwrap_or(512);
                        let osl = node.attr_int("osl").map(|v| v as u64).unwrap_or(128);
                        let par = Parallelism { tp: 1, pp: 1 };
                        let step =
                            decode_step_time(&m, d, par, isl + osl / 2, 1, &self.cfg.eff)
                                .total();
                        step * osl as f64
                    }
                    None => 0.5,
                }
            }
            "llm.infer" | "llm.diffuse" => {
                if is_cpu {
                    f64::INFINITY
                } else {
                    // Whole-model op (pre-decomposition): coarse estimate.
                    0.5 * 1979.0 / self.devices[class_idx].tflops_fp16
                }
            }
            // CPU-friendly ops: same wall time on CPU; accelerators
            // don't speed up network- or logic-bound work.
            _ => base,
        }
    }

    /// Build the assignment problem from an *annotated* graph.
    pub fn build_problem(&self, g: &Graph) -> Result<AssignmentProblem> {
        let classes = self.classes();
        let n_classes = classes.len();
        let cpu_idx = n_classes - 1;

        let mut tasks = Vec::new();
        let mut value_to_task: std::collections::BTreeMap<u32, usize> =
            std::collections::BTreeMap::new();

        for (ni, node) in g.nodes.iter().enumerate() {
            let mut latency_s = Vec::with_capacity(n_classes);
            let mut cost_usd = Vec::with_capacity(n_classes);
            let mut forbidden = Vec::new();
            let wants_accel = node
                .attr("wants_accel")
                .and_then(|a| a.as_bool())
                .unwrap_or(false);
            let overlap = prefix_overlap_of(g, ni);
            for j in 0..n_classes {
                let t = self.latency(node, j, overlap);
                if t.is_infinite() {
                    forbidden.push(j);
                    latency_s.push(1e9);
                    cost_usd.push(1e9);
                } else {
                    latency_s.push(t);
                    cost_usd.push(t * self.opex(j) / 3600.0);
                }
            }
            // Accelerator-hungry nodes must not land on CPU.
            if wants_accel && !forbidden.contains(&cpu_idx) {
                forbidden.push(cpu_idx);
            }
            let idx = tasks.len();
            for r in &node.results {
                value_to_task.insert(r.0, idx);
            }
            tasks.push(TaskSpec {
                name: format!("{}#{}", node.op, node.id.0),
                latency_s,
                cost_usd,
                capacity_use: 0.0,
                forbidden,
            });
        }

        // Edges: dataflow with transfer cost when classes differ,
        // priced by annotated est_bytes on the consumer (kv.transfer).
        let mut edges = Vec::new();
        for (ni, node) in g.nodes.iter().enumerate() {
            for o in &node.operands {
                if let Some(&src) = value_to_task.get(&o.0) {
                    let bytes = node.attr_f64("est_bytes").unwrap_or(1e6);
                    let mut lat = vec![vec![0.0; n_classes]; n_classes];
                    let mut cost = vec![vec![0.0; n_classes]; n_classes];
                    for a in 0..n_classes {
                        for b in 0..n_classes {
                            if a != b {
                                // Cross-class hop over the scale-out NIC.
                                let bw = 50e9 * self.cfg.eff.net_util;
                                lat[a][b] = bytes / bw + 1e-4;
                                cost[a][b] = bytes * self.cfg.gamma_usd_per_byte;
                            }
                        }
                    }
                    edges.push(EdgeSpec {
                        from: src,
                        to: ni,
                        latency_s: lat,
                        cost_usd: cost,
                    });
                }
            }
        }

        Ok(AssignmentProblem {
            classes,
            tasks,
            edges,
            sla: self.cfg.sla,
        })
    }

    /// Full pipeline: lower + annotate the graph, solve placement, and
    /// lower the result into a serializable [`ExecutionPlan`].
    pub fn plan(&self, g: &Graph) -> Result<ExecutionPlan> {
        let mut g = g.clone();
        let mut pm = PassManager::standard();
        pm.run(&mut g)?;
        let problem = self.build_problem(&g)?;
        if problem.tasks.is_empty() {
            return Err(Error::Opt("graph has no tasks".into()));
        }
        // Exact B&B for small graphs; edge-aware local search beyond
        // (inlined hierarchical agents can expose dozens of tasks).
        let assignment = problem.solve_auto()?;
        self.lower_to_execution_plan(&g, &problem, &assignment, pm.log.clone())
    }

    /// Lower a solved assignment into the unified plan artifact.
    fn lower_to_execution_plan(
        &self,
        g: &Graph,
        problem: &AssignmentProblem,
        assignment: &crate::opt::assignment::Assignment,
        pass_log: Vec<(String, bool)>,
    ) -> Result<ExecutionPlan> {
        // Model: first LLM-ish node carrying a resolvable `model` attr.
        let model = g
            .nodes
            .iter()
            .filter(|n| {
                Stage::of_op(&n.op) != Stage::Cpu
                    || n.op.starts_with("llm.")
                    || n.op.starts_with("moe.")
            })
            .filter_map(|n| n.attr_str("model"))
            .find(|m| by_short_name(m).is_some())
            .unwrap_or("")
            .to_string();
        let profile = by_short_name(&model);

        // Per-node bindings with dataflow deps and transfer estimates.
        let edges = g.dataflow_edges();
        let mut bindings = Vec::with_capacity(g.nodes.len());
        for (i, node) in g.nodes.iter().enumerate() {
            let j = assignment.choice[i];
            let stage = Stage::of_op(&node.op);
            let xfer_bytes = match (stage, &profile) {
                // Prefill → decode hands over the KV cache; size it from
                // the model profile at the node's annotated ISL.
                (Stage::LlmDecode, Some(m)) => {
                    let isl = node.attr_int("isl").map(|v| v as u64).unwrap_or(512);
                    crate::cost::kv::kv_cache_bytes(m, isl, 1)
                }
                _ => node.attr_f64("est_bytes").unwrap_or(1e6),
            };
            bindings.push(NodeBinding {
                op: node.op.clone(),
                class: problem.classes[j].name.clone(),
                stage,
                latency_s: problem.tasks[i].latency_s[j],
                cost_usd: problem.tasks[i].cost_usd[j],
                deps: edges
                    .iter()
                    .filter(|(_, to)| *to == i)
                    .map(|(from, _)| *from)
                    .collect(),
                xfer_bytes,
                // Expert decomposition annotates ~top_k/N per expert;
                // whole-stream nodes process every token.
                token_fraction: node
                    .attr_f64("token_fraction")
                    .unwrap_or(1.0)
                    .clamp(f64::MIN_POSITIVE, 1.0),
                // Same rule the cost model priced with, so the emitted
                // plan records the reuse assumption it was costed under.
                prefix_overlap: prefix_overlap_of(g, i),
            });
        }

        // Pipeline fleet: one group per distinct (role, class) among the
        // LLM bindings. TP×PP×batch via the §5 configuration explorer
        // for the primary prefill::decode pair; conservative defaults
        // elsewhere (or when the model is unknown).
        let distinct = |stage: Stage| -> Vec<String> {
            let mut out: Vec<String> = Vec::new();
            for b in &bindings {
                if b.stage == stage && b.class != "CPU" && !out.contains(&b.class) {
                    out.push(b.class.clone());
                }
            }
            out
        };
        let prefill_classes = distinct(Stage::LlmPrefill);
        let decode_classes = distinct(Stage::LlmDecode);

        let explored = match (&profile, prefill_classes.first(), decode_classes.first()) {
            (Some(m), Some(pc), Some(dc)) => {
                let (pd, dd) = (
                    crate::cost::hardware::by_name(pc),
                    crate::cost::hardware::by_name(dc),
                );
                match (pd, dd) {
                    (Some(pd), Some(dd)) => {
                        let shape = g
                            .nodes
                            .iter()
                            .find(|n| Stage::of_op(&n.op) == Stage::LlmDecode)
                            .map(|n| SeqShape {
                                isl: n.attr_int("isl").map(|v| v as u64).unwrap_or(512),
                                osl: n.attr_int("osl").map(|v| v as u64).unwrap_or(128),
                            })
                            .unwrap_or(SeqShape { isl: 512, osl: 128 });
                        let opts = ExploreOpts {
                            eff: self.cfg.eff,
                            opex: self.cfg.opex,
                            terms: self.cfg.terms,
                            ..ExploreOpts::default()
                        };
                        best_config(m, &pd, &dd, shape, SlaMode::Throughput, &opts)
                    }
                    _ => None,
                }
            }
            _ => None,
        };

        let mut pipelines = Vec::new();
        let mut chassis = 0u32;
        for (role, classes, replicas, default_batch) in [
            (
                Role::Prefill,
                &prefill_classes,
                self.cfg.prefill_replicas.max(1),
                8u64,
            ),
            (
                Role::Decode,
                &decode_classes,
                self.cfg.decode_replicas.max(1),
                32u64,
            ),
        ] {
            for (ci, class) in classes.iter().enumerate() {
                let (par, max_batch) = match (&explored, role, ci) {
                    (Some(cfg), Role::Prefill, 0) => {
                        (cfg.prefill.par, cfg.prefill.batch)
                    }
                    (Some(cfg), Role::Decode, 0) => (cfg.decode.par, cfg.decode.batch),
                    _ => (Parallelism { tp: 1, pp: 1 }, default_batch),
                };
                pipelines.push(PipelineBinding {
                    role,
                    device: class.clone(),
                    tp: par.tp,
                    pp: par.pp,
                    max_batch,
                    replicas,
                    chassis,
                });
                chassis += replicas;
            }
        }

        // Serving-side decode cap follows the planned decode pipelines,
        // so simulation and serving run the same batching policy (the
        // prefill buckets stay config-driven: they must match the
        // AOT-compiled artifact set, not the fleet).
        let mut batching = self.cfg.batching.clone();
        if let Some(mb) = pipelines
            .iter()
            .filter(|p| p.role == Role::Decode)
            .map(|p| p.max_batch)
            .max()
        {
            batching.max_decode_batch = mb as usize;
        }

        let plan = ExecutionPlan {
            agent: g.name.clone(),
            model,
            sla: self.cfg.sla.into(),
            bindings,
            pipelines,
            batching,
            admission: self.cfg.admission.clone(),
            fabric: self.cfg.fabric.clone(),
            cpu_workers: self.cfg.cpu_workers,
            cost_usd: assignment.cost_usd,
            latency_s: assignment.latency_s,
            pass_log,
        };
        plan.validate()?;
        // The planner holds itself to the same static analysis every
        // consumer runs: a freshly-lowered plan must carry no
        // Error-severity diagnostics (debug builds assert with the
        // diagnostics table; release builds skip the check).
        crate::plan::verify::debug_assert_clean(&plan);
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agents;

    fn planner() -> Planner {
        Planner::new(PlannerConfig::default())
    }

    #[test]
    fn voice_agent_non_llm_on_cpu() {
        // §5.3: "Our optimization framework places the non-LLM
        // components of the voice agent on CPUs."
        let g = agents::voice_agent("8b-fp16", 512, 256);
        let plan = planner().plan(&g).unwrap();
        assert_eq!(plan.class_of("stt.transcribe"), Some("CPU"));
        assert_eq!(plan.class_of("tts.synthesize"), Some("CPU"));
        // LLM stages land on accelerators.
        let prefill_class = plan.class_of("llm.prefill").unwrap();
        assert_ne!(prefill_class, "CPU");
        let decode_class = plan.class_of("llm.decode").unwrap();
        assert_ne!(decode_class, "CPU");
    }

    #[test]
    fn prefill_and_decode_classes_can_differ() {
        // The disaggregation headline: with a loose SLA the cheapest
        // prefill device and cheapest decode device are chosen
        // independently (heterogeneous pairing).
        let g = agents::voice_agent("70b-fp8", 4096, 512);
        let mut p = planner();
        p.cfg.sla = Sla::None;
        let plan = p.plan(&g).unwrap();
        let pf = plan.class_of("llm.prefill").unwrap();
        let dc = plan.class_of("llm.decode").unwrap();
        // Not asserting a specific pair (calibration-sensitive), but
        // both must be accelerators and the plan must be finite-cost.
        assert_ne!(pf, "CPU");
        assert_ne!(dc, "CPU");
        assert!(plan.cost_usd < 1.0);
    }

    #[test]
    fn tight_sla_shifts_to_faster_hardware() {
        let g = agents::voice_agent("8b-fp16", 512, 128);
        let mut loose = planner();
        loose.cfg.sla = Sla::None;
        let plan_loose = loose.plan(&g).unwrap();

        // The voice agent's CPU stages (STT/TTS) put a floor on latency,
        // so only a mild tightening is guaranteed feasible.
        let mut tight = planner();
        tight.cfg.sla = Sla::EndToEnd(plan_loose.latency_s * 0.99);
        let plan_tight = tight.plan(&g).unwrap();
        assert!(plan_tight.latency_s <= plan_loose.latency_s);
        assert!(plan_tight.cost_usd >= plan_loose.cost_usd - 1e-12);
    }

    #[test]
    fn impossible_sla_reported_infeasible() {
        let g = agents::voice_agent("8b-fp16", 512, 128);
        let mut p = planner();
        p.cfg.sla = Sla::EndToEnd(1e-6);
        assert!(p.plan(&g).is_err());
    }

    #[test]
    fn fanout_sibling_prefills_are_priced_as_cache_hits() {
        use crate::ir::attr::Attr;
        use crate::ir::GraphBuilder;
        let mut b = GraphBuilder::new("fanout");
        let q = b.op("io.input", &[]);
        let mk = |b: &mut GraphBuilder, extra: &[(&str, Attr)]| {
            let mut attrs: Vec<(&str, Attr)> = vec![
                ("model", "8b-fp16".into()),
                ("isl", Attr::Int(4096)),
            ];
            attrs.extend_from_slice(extra);
            b.op_with("llm.prefill", &[q], &attrs)
        };
        let _first = mk(&mut b, &[]);
        let _sibling = mk(&mut b, &[]); // identical operands ⇒ reuse
        let _pinned = mk(&mut b, &[("prefix_overlap", Attr::Float(0.5))]);
        let g = b.finish();

        let problem = planner().build_problem(&g).unwrap();
        // Tasks: 0 io.input, 1 first prefill, 2 structural sibling,
        // 3 explicit 50% overlap. On every accelerator class the
        // sibling collapses to the 1-token floor, the pinned node sits
        // strictly between, and the first pays full price.
        let accel_classes = problem.classes.len() - 1;
        for j in 0..accel_classes {
            let full = problem.tasks[1].latency_s[j];
            let sib = problem.tasks[2].latency_s[j];
            let half = problem.tasks[3].latency_s[j];
            assert!(sib < half && half < full, "class {j}: {sib} {half} {full}");
        }
    }

    #[test]
    fn pass_log_recorded() {
        let g = agents::voice_agent("8b-fp16", 512, 128);
        let plan = planner().plan(&g).unwrap();
        assert!(plan
            .pass_log
            .iter()
            .any(|(name, changed)| name == "decompose-llm" && *changed));
    }
}
