//! Utilization-driven autoscaling with hysteresis (§4.1 "Automatically
//! scales agentic workloads across heterogeneous hardware resources
//! based on load and utilization").
//!
//! Two granularities live here:
//!
//! * [`Autoscaler`] — one per pipeline *role*, deciding the role's
//!   replica total from the aggregate pressure signal;
//! * [`GroupScaler`] + [`score_groups`] — per pipeline *group* (a
//!   hardware generation within a role): streak detection over
//!   per-group utilization, and the cost-model score that decides
//!   *which* group a scale delta lands on — scale-ups buy the cheapest
//!   $/throughput capacity, scale-downs retire the worst-TCO capacity
//!   first (the paper's mixed-fleet efficiency argument, MARS-style
//!   heterogeneous co-scheduling).

use std::collections::BTreeMap;

use crate::cost::hardware::by_name;
use crate::cost::tco::{opex_usd_per_hour, FinanceTerms, OpexModel};
use crate::plan::{ExecutionPlan, Role};

/// Scaling decision for one pipeline role.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    ScaleUp(u32),
    ScaleDown(u32),
    Hold,
}

#[derive(Debug, Clone)]
pub struct AutoscalerConfig {
    /// Utilization above which we add capacity.
    pub high_watermark: f64,
    /// Utilization below which we remove capacity.
    pub low_watermark: f64,
    /// Consecutive observations required before acting (hysteresis).
    pub patience: u32,
    pub min_pipelines: u32,
    pub max_pipelines: u32,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            high_watermark: 0.85,
            low_watermark: 0.30,
            patience: 3,
            min_pipelines: 1,
            max_pipelines: 64,
        }
    }
}

/// Per-role autoscaler.
#[derive(Debug)]
pub struct Autoscaler {
    cfg: AutoscalerConfig,
    pub current: u32,
    high_streak: u32,
    low_streak: u32,
}

impl Autoscaler {
    pub fn new(cfg: AutoscalerConfig, initial: u32) -> Autoscaler {
        let current = initial.clamp(cfg.min_pipelines, cfg.max_pipelines);
        Autoscaler {
            cfg,
            current,
            high_streak: 0,
            low_streak: 0,
        }
    }

    /// Feed one utilization observation; returns the decision taken
    /// (already applied to `self.current`).
    pub fn observe(&mut self, utilization: f64) -> ScaleDecision {
        if utilization >= self.cfg.high_watermark {
            self.high_streak += 1;
            self.low_streak = 0;
        } else if utilization <= self.cfg.low_watermark {
            self.low_streak += 1;
            self.high_streak = 0;
        } else {
            self.high_streak = 0;
            self.low_streak = 0;
        }

        if self.high_streak >= self.cfg.patience && self.current < self.cfg.max_pipelines
        {
            self.high_streak = 0;
            // Scale up proportionally to overload (at least 1).
            let add = ((self.current as f64 * 0.5).ceil() as u32)
                .min(self.cfg.max_pipelines - self.current)
                .max(1);
            self.current += add;
            return ScaleDecision::ScaleUp(add);
        }
        if self.low_streak >= self.cfg.patience && self.current > self.cfg.min_pipelines
        {
            self.low_streak = 0;
            let remove = ((self.current as f64 * 0.25).floor() as u32)
                .min(self.current - self.cfg.min_pipelines)
                .max(1);
            self.current -= remove;
            return ScaleDecision::ScaleDown(remove);
        }
        ScaleDecision::Hold
    }
}

// ---------------------------------------------------------------------
// Per-group scoring and streak detection
// ---------------------------------------------------------------------

/// Cost/throughput standing of one pipeline group, derived from the
/// planner's cost model ([`crate::cost`]): the derived opex of the
/// group's device times its TP×PP footprint, over a role-appropriate
/// throughput proxy (decode is HBM-bandwidth-bound, prefill
/// compute-bound). `score` is $ per unit of throughput per hour —
/// **lower is cheaper capacity**.
#[derive(Debug, Clone)]
pub struct GroupScore {
    /// Index into `ExecutionPlan::pipelines`.
    pub group: usize,
    /// The group's canonical shape key ([`crate::plan::PipelineBinding::shape_key`]).
    pub key: String,
    /// Derived operating cost of one replica, $/hour.
    pub usd_per_hour: f64,
    /// Relative serving throughput of one replica (role-appropriate
    /// roofline proxy; comparable within a role only).
    pub throughput: f64,
    /// usd_per_hour / throughput — the TCO ranking the retarget uses.
    pub score: f64,
}

/// Score every pipeline group of `role`. Unknown devices score
/// infinitely expensive, so they are always first to retire and never
/// chosen for growth.
pub fn score_groups(plan: &ExecutionPlan, role: Role) -> Vec<GroupScore> {
    plan.pipelines
        .iter()
        .enumerate()
        .filter(|(_, p)| p.role == role)
        .map(|(g, p)| {
            let devices = (p.tp * p.pp).max(1) as f64;
            let (usd_per_hour, throughput) = match by_name(&p.device) {
                Some(d) => {
                    let usd = devices
                        * opex_usd_per_hour(&d, OpexModel::Derived, &FinanceTerms::default());
                    let per_device = match role {
                        Role::Decode => d.mem_bw_gbps,
                        Role::Prefill => d.tflops_fp16,
                    };
                    (usd, per_device * devices)
                }
                None => (f64::INFINITY, 1.0),
            };
            GroupScore {
                group: g,
                key: p.shape_key(),
                usd_per_hour,
                throughput,
                score: usd_per_hour / throughput.max(1e-9),
            }
        })
        .collect()
}

/// Deterministic TCO ordering over [`GroupScore`]s: by score, ties by
/// declaration order. The single comparator every consumer ranks with,
/// so "which group is cheapest" can never diverge between the decision
/// record, the retarget distribution, and the migration routing.
pub fn rank(a: &GroupScore, b: &GroupScore) -> std::cmp::Ordering {
    a.score
        .partial_cmp(&b.score)
        .unwrap_or(std::cmp::Ordering::Equal)
        .then(a.group.cmp(&b.group))
}

/// The cheapest-$/throughput group (best to grow).
pub fn cheapest(scores: &[GroupScore]) -> Option<&GroupScore> {
    scores.iter().min_by(|a, b| rank(a, b))
}

/// The worst-TCO group (first to retire).
pub fn worst(scores: &[GroupScore]) -> Option<&GroupScore> {
    scores.iter().max_by(|a, b| rank(a, b))
}

/// A group whose pressure streak crossed a watermark for `patience`
/// consecutive windows.
#[derive(Debug, Clone, PartialEq)]
pub struct GroupFired {
    /// Shape key of the group.
    pub key: String,
    /// True = sustained hot (≥ high watermark); false = sustained cold
    /// (≤ low watermark).
    pub hot: bool,
}

/// Per-group hysteresis: the [`Autoscaler`] streak rule applied to each
/// group's own pressure signal. Where the role scaler answers "how many
/// replicas in total", this answers "which groups are persistently hot
/// or idle" — the trigger for pure cross-group rebalances that move
/// replicas between hardware generations without changing the total.
#[derive(Debug)]
pub struct GroupScaler {
    cfg: AutoscalerConfig,
    /// key → (hot streak, cold streak).
    streaks: BTreeMap<String, (u32, u32)>,
}

impl GroupScaler {
    pub fn new(cfg: AutoscalerConfig) -> GroupScaler {
        GroupScaler {
            cfg,
            streaks: BTreeMap::new(),
        }
    }

    /// Feed one window of per-group pressures; returns the groups whose
    /// streak just crossed patience. Hot streaks reset on firing (an
    /// *edge* signal, exactly like [`Autoscaler::observe`] — they
    /// re-fire every `patience` hot windows). Cold streaks keep
    /// counting (fired once, at the crossing), so
    /// [`GroupScaler::sustained_cold`] stays true for as long as the
    /// group actually idles — the *level* signal a rebalance donor is
    /// picked by, which keeps a hot edge and a cold level pairable even
    /// when their crossings land on different windows. Groups absent
    /// from `pressures` (retired by a fleet change) are forgotten.
    pub fn observe(&mut self, pressures: &[(String, f64)]) -> Vec<GroupFired> {
        let live: std::collections::BTreeSet<&String> =
            pressures.iter().map(|(k, _)| k).collect();
        self.streaks.retain(|k, _| live.contains(k));
        let mut fired = Vec::new();
        for (key, p) in pressures {
            let s = self.streaks.entry(key.clone()).or_insert((0, 0));
            if *p >= self.cfg.high_watermark {
                s.0 += 1;
                s.1 = 0;
            } else if *p <= self.cfg.low_watermark {
                s.1 += 1;
                s.0 = 0;
            } else {
                *s = (0, 0);
            }
            if s.0 >= self.cfg.patience {
                s.0 = 0;
                fired.push(GroupFired {
                    key: key.clone(),
                    hot: true,
                });
            } else if self.cfg.patience > 0 && s.1 == self.cfg.patience {
                fired.push(GroupFired {
                    key: key.clone(),
                    hot: false,
                });
            }
        }
        fired
    }

    /// Has `key` sat at/below the low watermark for ≥ `patience`
    /// consecutive windows (and not recovered since)? The donor-side
    /// condition for cross-group rebalances.
    pub fn sustained_cold(&self, key: &str) -> bool {
        self.streaks
            .get(key)
            .is_some_and(|s| s.1 >= self.cfg.patience)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scaler(initial: u32) -> Autoscaler {
        Autoscaler::new(AutoscalerConfig::default(), initial)
    }

    #[test]
    fn scales_up_after_patience() {
        let mut a = scaler(2);
        assert_eq!(a.observe(0.95), ScaleDecision::Hold);
        assert_eq!(a.observe(0.95), ScaleDecision::Hold);
        assert_eq!(a.observe(0.95), ScaleDecision::ScaleUp(1));
        assert_eq!(a.current, 3);
    }

    #[test]
    fn mid_band_resets_streak() {
        let mut a = scaler(2);
        a.observe(0.95);
        a.observe(0.95);
        assert_eq!(a.observe(0.5), ScaleDecision::Hold); // streak reset
        assert_eq!(a.observe(0.95), ScaleDecision::Hold);
        assert_eq!(a.observe(0.95), ScaleDecision::Hold);
        assert_eq!(a.observe(0.95), ScaleDecision::ScaleUp(1));
    }

    #[test]
    fn scales_down_but_respects_min() {
        let mut a = scaler(2);
        for _ in 0..2 {
            assert_eq!(a.observe(0.1), ScaleDecision::Hold);
        }
        assert_eq!(a.observe(0.1), ScaleDecision::ScaleDown(1));
        assert_eq!(a.current, 1);
        // At min: never goes below.
        for _ in 0..10 {
            assert_ne!(a.observe(0.0), ScaleDecision::ScaleDown(1));
        }
        assert_eq!(a.current, 1);
    }

    #[test]
    fn respects_max() {
        let mut a = Autoscaler::new(
            AutoscalerConfig {
                max_pipelines: 3,
                ..Default::default()
            },
            3,
        );
        for _ in 0..10 {
            assert_eq!(a.observe(0.99), ScaleDecision::Hold);
        }
        assert_eq!(a.current, 3);
    }

    #[test]
    fn proportional_growth_on_large_fleets() {
        let mut a = scaler(8);
        a.observe(0.9);
        a.observe(0.9);
        assert_eq!(a.observe(0.9), ScaleDecision::ScaleUp(4));
        assert_eq!(a.current, 12);
    }

    #[test]
    fn group_scores_follow_the_cost_model() {
        let plan = crate::plan::presets::mixed_generation("8b-fp16", "H100", "A100", 2, 2);
        let scores = score_groups(&plan, Role::Decode);
        assert_eq!(scores.len(), 2);
        // Scores are the cost model verbatim: $/hr over the bandwidth
        // proxy, per replica.
        for s in &scores {
            let p = &plan.pipelines[s.group];
            let d = by_name(&p.device).unwrap();
            let usd = opex_usd_per_hour(&d, OpexModel::Derived, &FinanceTerms::default());
            assert!((s.usd_per_hour - usd).abs() < 1e-12, "{}", s.key);
            assert!((s.throughput - d.mem_bw_gbps).abs() < 1e-9);
            assert!((s.score - usd / d.mem_bw_gbps).abs() < 1e-12);
            assert!(s.key.starts_with("decode "));
        }
        // Doubling TP doubles both cost and throughput: score invariant.
        let mut tp2 = plan.clone();
        tp2.pipelines[1].tp = 2;
        let s1 = &score_groups(&plan, Role::Decode)[0];
        let s2 = &score_groups(&tp2, Role::Decode)[0];
        assert!((s1.score - s2.score).abs() < 1e-12);
        assert!((s2.usd_per_hour - 2.0 * s1.usd_per_hour).abs() < 1e-9);
        // Prefill uses the compute proxy.
        let pre = score_groups(&plan, Role::Prefill);
        assert_eq!(pre.len(), 1);
        let h100 = by_name("H100").unwrap();
        assert!((pre[0].throughput - h100.tflops_fp16).abs() < 1e-9);
    }

    #[test]
    fn unknown_device_scores_infinitely_expensive() {
        let mut plan = crate::plan::presets::mixed_generation("8b-fp16", "H100", "A100", 1, 1);
        plan.pipelines[2].device = "TPUv9".into();
        let scores = score_groups(&plan, Role::Decode);
        assert!(scores[1].score.is_infinite());
        assert!(scores[0].score.is_finite());
    }

    #[test]
    fn group_scaler_fires_per_group_after_patience() {
        let cfg = AutoscalerConfig {
            patience: 2,
            ..Default::default()
        };
        let mut gs = GroupScaler::new(cfg);
        let window = |hot: f64, cold: f64| {
            vec![("a".to_string(), hot), ("b".to_string(), cold)]
        };
        assert!(gs.observe(&window(0.95, 0.1)).is_empty());
        assert!(!gs.sustained_cold("b"), "one cold window is not sustained");
        let fired = gs.observe(&window(0.95, 0.1));
        assert_eq!(fired.len(), 2);
        assert!(fired.contains(&GroupFired { key: "a".into(), hot: true }));
        assert!(fired.contains(&GroupFired { key: "b".into(), hot: false }));
        // Hot resets (edge) and re-arms; cold keeps counting (level):
        // the fired list is empty but the donor signal stays up.
        assert!(gs.observe(&window(0.95, 0.1)).is_empty());
        assert!(gs.sustained_cold("b"), "cold level persists past the edge");
        assert!(!gs.sustained_cold("a"));
        // Mid-band resets; vanished groups are forgotten.
        gs.observe(&window(0.5, 0.5));
        let only_a = [("a".to_string(), 0.95)];
        gs.observe(&only_a);
        let fired = gs.observe(&only_a);
        assert_eq!(fired.len(), 1);
        assert_eq!(fired[0].key, "a");
    }
}
