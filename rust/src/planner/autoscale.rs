//! Utilization-driven autoscaling with hysteresis (§4.1 "Automatically
//! scales agentic workloads across heterogeneous hardware resources
//! based on load and utilization").

/// Scaling decision for one pipeline role.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    ScaleUp(u32),
    ScaleDown(u32),
    Hold,
}

#[derive(Debug, Clone)]
pub struct AutoscalerConfig {
    /// Utilization above which we add capacity.
    pub high_watermark: f64,
    /// Utilization below which we remove capacity.
    pub low_watermark: f64,
    /// Consecutive observations required before acting (hysteresis).
    pub patience: u32,
    pub min_pipelines: u32,
    pub max_pipelines: u32,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        AutoscalerConfig {
            high_watermark: 0.85,
            low_watermark: 0.30,
            patience: 3,
            min_pipelines: 1,
            max_pipelines: 64,
        }
    }
}

/// Per-role autoscaler.
#[derive(Debug)]
pub struct Autoscaler {
    cfg: AutoscalerConfig,
    pub current: u32,
    high_streak: u32,
    low_streak: u32,
}

impl Autoscaler {
    pub fn new(cfg: AutoscalerConfig, initial: u32) -> Autoscaler {
        let current = initial.clamp(cfg.min_pipelines, cfg.max_pipelines);
        Autoscaler {
            cfg,
            current,
            high_streak: 0,
            low_streak: 0,
        }
    }

    /// Feed one utilization observation; returns the decision taken
    /// (already applied to `self.current`).
    pub fn observe(&mut self, utilization: f64) -> ScaleDecision {
        if utilization >= self.cfg.high_watermark {
            self.high_streak += 1;
            self.low_streak = 0;
        } else if utilization <= self.cfg.low_watermark {
            self.low_streak += 1;
            self.high_streak = 0;
        } else {
            self.high_streak = 0;
            self.low_streak = 0;
        }

        if self.high_streak >= self.cfg.patience && self.current < self.cfg.max_pipelines
        {
            self.high_streak = 0;
            // Scale up proportionally to overload (at least 1).
            let add = ((self.current as f64 * 0.5).ceil() as u32)
                .min(self.cfg.max_pipelines - self.current)
                .max(1);
            self.current += add;
            return ScaleDecision::ScaleUp(add);
        }
        if self.low_streak >= self.cfg.patience && self.current > self.cfg.min_pipelines
        {
            self.low_streak = 0;
            let remove = ((self.current as f64 * 0.25).floor() as u32)
                .min(self.current - self.cfg.min_pipelines)
                .max(1);
            self.current -= remove;
            return ScaleDecision::ScaleDown(remove);
        }
        ScaleDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scaler(initial: u32) -> Autoscaler {
        Autoscaler::new(AutoscalerConfig::default(), initial)
    }

    #[test]
    fn scales_up_after_patience() {
        let mut a = scaler(2);
        assert_eq!(a.observe(0.95), ScaleDecision::Hold);
        assert_eq!(a.observe(0.95), ScaleDecision::Hold);
        assert_eq!(a.observe(0.95), ScaleDecision::ScaleUp(1));
        assert_eq!(a.current, 3);
    }

    #[test]
    fn mid_band_resets_streak() {
        let mut a = scaler(2);
        a.observe(0.95);
        a.observe(0.95);
        assert_eq!(a.observe(0.5), ScaleDecision::Hold); // streak reset
        assert_eq!(a.observe(0.95), ScaleDecision::Hold);
        assert_eq!(a.observe(0.95), ScaleDecision::Hold);
        assert_eq!(a.observe(0.95), ScaleDecision::ScaleUp(1));
    }

    #[test]
    fn scales_down_but_respects_min() {
        let mut a = scaler(2);
        for _ in 0..2 {
            assert_eq!(a.observe(0.1), ScaleDecision::Hold);
        }
        assert_eq!(a.observe(0.1), ScaleDecision::ScaleDown(1));
        assert_eq!(a.current, 1);
        // At min: never goes below.
        for _ in 0..10 {
            assert_ne!(a.observe(0.0), ScaleDecision::ScaleDown(1));
        }
        assert_eq!(a.current, 1);
    }

    #[test]
    fn respects_max() {
        let mut a = Autoscaler::new(
            AutoscalerConfig {
                max_pipelines: 3,
                ..Default::default()
            },
            3,
        );
        for _ in 0..10 {
            assert_eq!(a.observe(0.99), ScaleDecision::Hold);
        }
        assert_eq!(a.current, 3);
    }

    #[test]
    fn proportional_growth_on_large_fleets() {
        let mut a = scaler(8);
        a.observe(0.9);
        a.observe(0.9);
        assert_eq!(a.observe(0.9), ScaleDecision::ScaleUp(4));
        assert_eq!(a.current, 12);
    }
}
