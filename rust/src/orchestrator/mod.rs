//! The dynamic orchestration loop — the paper's third building block:
//! "a dynamic orchestration system that can place the granular
//! components across a heterogeneous compute infrastructure and stitch
//! them together while meeting an end-to-end SLA" (§4.1).
//!
//! The subsystem closes the loop the planner left open. `planner`
//! produces an [`ExecutionPlan`](crate::plan::ExecutionPlan); this
//! module owns that plan's *runtime lifecycle*:
//!
//! ```text
//!            ┌────────────────────────────────────────────────┐
//!            ▼                                                │
//!   observe (WindowStats: util, backlog, SLA attainment)      │
//!            │                                                │
//!   decide  (per-role Autoscaler, hysteresis)                 │ apply
//!            │                                                │ (Executor)
//!   re-plan (planner::Planner / structural retarget           │
//!            │          → NEW ExecutionPlan)                  │
//!   diff    (plan::PlanDiff: added/removed/resized/policy)    │
//!            │                                                │
//!   migrate (planner::migration → capacity-safe MigrationPlan)│
//!            └────────────────────────────────────────────────┘
//! ```
//!
//! Every iteration is recorded in a replayable [`Timeline`] (plans,
//! diffs, decisions, migrations, per-window SLA attainment) that
//! round-trips losslessly through [`crate::util::json`].
//!
//! Execution sits behind one [`Executor`] trait with two backends:
//!
//! * [`SimExecutor`] — drives [`crate::cluster::dag::DagSim`] with a
//!   time-varying fleet, so orchestration policies are evaluated
//!   end-to-end against traced load swings (bursty arrivals, drain/
//!   activate mid-run, KV migrations occupying real fabric links);
//! * [`LiveExecutor`] — reconfigures a running
//!   [`crate::server::Server`] between request windows, deriving the
//!   serving policy of each new plan via `ServerConfig::from_plan`.
//!
//! CLI: `agentic-hetero orchestrate --plan x.json --trace bursty --out
//! timeline.json`.

pub mod diff_apply;
#[path = "loop.rs"]
pub mod control;
pub mod timeline;

pub use control::{
    attach_window_attribution, chat_request_of, reconcile_replan, Executor, LiveExecutor,
    Orchestrator, OrchestratorConfig, PlanChange, PlanRejection, SimExecutor,
};
pub use diff_apply::{
    capacity_trajectory, converges, lower_diff, rebalance, retarget, retune_token_fractions,
    shape_map_of,
};
pub use timeline::{Timeline, TimelineEvent};
