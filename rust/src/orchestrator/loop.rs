//! The closed-loop controller: observe → decide → re-plan → diff →
//! migrate → apply, plus the two [`Executor`] backends that drive it.
//!
//! The [`Orchestrator`] is a *pure decision engine*: executors feed it
//! [`WindowStats`] and it answers with an optional [`PlanChange`]
//! (target plan + typed diff + capacity-safe migration). That keeps
//! the loop testable without any backend and lets both backends share
//! every policy knob:
//!
//! * [`SimExecutor`] plugs the orchestrator into
//!   [`DagSim::run_controlled`] as a [`FleetController`] — load swings
//!   from a traced workload drive real fleet changes in the simulator;
//! * [`LiveExecutor`] chunks a request stream into windows against a
//!   running [`Server`], re-deriving `ServerConfig::from_plan` whenever
//!   the orchestrator re-plans (reconfiguration happens *between*
//!   requests, never under one).

// The control loop runs on serving threads: a panic here takes the
// whole fleet down, so fallible paths must return typed errors.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::sync::Arc;

use crate::cluster::arrivals::ArrivalProcess;
use crate::cluster::dag::{DagSim, FleetChangeStats, FleetController, GroupWindow, WindowStats};
use crate::cluster::sim::SimReport;
use crate::cluster::trace::Request;
use crate::ir::graph::Graph;
use crate::obs::critical_path::{attribute_all, attribute_windows, SlaAttribution, BUCKETS};
use crate::obs::trace::{Span, TraceSink};
use crate::obs::MetricsRegistry;
use crate::plan::verify;
use crate::plan::{ExecutionPlan, PlanDiff, Role, SlaSpec};
use crate::planner::autoscale::{
    cheapest, rank, score_groups, worst, Autoscaler, AutoscalerConfig, GroupScaler, GroupScore,
    ScaleDecision,
};
use crate::planner::migration::{role_replicas, MigrationPlan};
use crate::planner::plan::Planner;
use crate::server::{ChatRequest, Server, ServerConfig};
use crate::{Error, Result};

use super::diff_apply::{lower_diff, rebalance, retarget, retune_token_fractions, role_capacity};
use super::timeline::{Timeline, TimelineEvent};

/// Control-loop knobs.
#[derive(Debug, Clone)]
pub struct OrchestratorConfig {
    /// Observation window length, seconds (sim) — the live backend uses
    /// request chunks instead but records the same cadence.
    pub window_s: f64,
    /// Per-role autoscaler policy (watermarks, patience, bounds).
    pub autoscale: AutoscalerConfig,
    /// Queue backlog equal to `backlog_factor ×` the role's batch
    /// capacity reads as full (1.0) pressure even when utilization
    /// lags (queues grow before device-time catches up).
    pub backlog_factor: f64,
    /// `cpu_workers` autoscaler consuming the `host_util` observation:
    /// sustained host-pool pressure resizes the plan's CPU worker slots
    /// (the count here is *workers*, not pipelines). `None` keeps the
    /// host pool fixed.
    pub cpu_autoscale: Option<AutoscalerConfig>,
}

impl Default for OrchestratorConfig {
    fn default() -> Self {
        OrchestratorConfig {
            window_s: 5.0,
            autoscale: AutoscalerConfig::default(),
            backlog_factor: 1.0,
            cpu_autoscale: None,
        }
    }
}

impl OrchestratorConfig {
    /// Pull the `[orchestrator]` knobs out of a deployment config.
    pub fn from_deploy(cfg: &crate::config::DeployConfig) -> OrchestratorConfig {
        OrchestratorConfig {
            window_s: cfg.orch_window_s,
            autoscale: AutoscalerConfig {
                high_watermark: cfg.orch_high_watermark,
                low_watermark: cfg.orch_low_watermark,
                patience: cfg.orch_patience,
                min_pipelines: cfg.orch_min_pipelines,
                max_pipelines: cfg.orch_max_pipelines,
            },
            backlog_factor: 1.0,
            // Host pool follows the same watermarks/patience, with its
            // own worker-count ceiling (`[orchestrator] max_cpu_workers`;
            // 0 keeps the pool fixed).
            cpu_autoscale: if cfg.orch_max_cpu_workers == 0 {
                None
            } else {
                Some(AutoscalerConfig {
                    high_watermark: cfg.orch_high_watermark,
                    low_watermark: cfg.orch_low_watermark,
                    patience: cfg.orch_patience,
                    min_pipelines: 1,
                    max_pipelines: cfg.orch_max_cpu_workers,
                })
            },
        }
    }
}

/// A re-plan the orchestrator refused to adopt mid-run, with the reason
/// — recorded as a typed [`TimelineEvent::Rejection`] and surfaced on
/// the [`PlanChange`] so executors (and their operators) see *why* the
/// fleet kept its current class layout instead of the change silently
/// vanishing.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanRejection {
    /// Pipeline role whose class layout the rejected plan would move.
    pub role: String,
    /// Shape key of the live pipeline group the rejected change
    /// targeted (`None` = the role's primary group).
    pub group: Option<String>,
    pub reason: String,
}

/// What one loop iteration decided: the new target plan, the typed
/// diff from the live plan, the migration that realizes it, and any
/// re-plan the loop had to reject along the way.
#[derive(Debug, Clone)]
pub struct PlanChange {
    pub target: ExecutionPlan,
    pub diff: PlanDiff,
    pub migration: MigrationPlan,
    pub rejections: Vec<PlanRejection>,
}

/// Decide whether a freshly-planned layout can replace `current`
/// mid-run. In-flight jobs keep routing by the current plan's (role,
/// class) layout, so a fresh plan that moves any role's classes is
/// rejected (typed, per role) and the current plan is structurally
/// retargeted instead.
pub fn reconcile_replan(
    current: &ExecutionPlan,
    fresh: ExecutionPlan,
) -> (ExecutionPlan, Vec<PlanRejection>) {
    // The class-compatibility rule itself lives in the static analyzer
    // (AH050) so the lint CLI, the property suite, and this loop agree
    // on one definition; this shim only converts its findings into the
    // runtime's typed rejection record.
    let rejections: Vec<PlanRejection> = verify::verify_replan(current, &fresh)
        .into_iter()
        .map(|rd| PlanRejection {
            role: rd.role.name().to_string(),
            group: rd.group,
            reason: rd.diag.message,
        })
        .collect();
    if rejections.is_empty() {
        (fresh, rejections)
    } else {
        (current.clone(), rejections)
    }
}

/// The decision engine. Feed it window observations; it drives the
/// per-role autoscalers, re-plans, diffs, and lowers migrations —
/// recording everything in a [`Timeline`].
pub struct Orchestrator {
    pub cfg: OrchestratorConfig,
    pub metrics: Arc<MetricsRegistry>,
    current: ExecutionPlan,
    prefill_scaler: Autoscaler,
    decode_scaler: Autoscaler,
    /// Per-group streak detection over the executors' per-group window
    /// signals: a group persistently hot while a sibling idles triggers
    /// a cross-group rebalance (replicas move between hardware
    /// generations; the role total stays put).
    group_scaler: GroupScaler,
    /// Present when `cfg.cpu_autoscale` is set: scales `cpu_workers`
    /// from the measured host-pool utilization.
    host_scaler: Option<Autoscaler>,
    /// When attached, re-plans run the full slow path (IR → assignment
    /// → plan) instead of structurally retargeting the current plan.
    planner: Option<(Planner, Graph)>,
    timeline: Timeline,
    plan_seq: u64,
}

/// One pending cross-group move: `amount` replicas of `role` from the
/// group keyed `from` to the group keyed `to`.
#[derive(Debug, Clone)]
struct PendingRebalance {
    role: Role,
    from: String,
    to: String,
    amount: u32,
}

/// The one pressure rule both granularities are judged by: utilization
/// floored by queue backlog normalized against `capacity` (already
/// scaled by the backlog factor), clamped to [0, 1].
fn pressure_signal(util: f64, queue: usize, capacity: f64) -> f64 {
    let backlog = if capacity > 0.0 {
        (queue as f64 / capacity).min(1.0)
    } else {
        0.0
    };
    util.max(backlog).clamp(0.0, 1.0)
}

impl Orchestrator {
    pub fn new(
        cfg: OrchestratorConfig,
        initial: ExecutionPlan,
        trace_name: &str,
        backend: &str,
    ) -> Result<Orchestrator> {
        initial.validate()?;
        let pre0 = role_replicas(&initial, Role::Prefill).max(1);
        let dec0 = role_replicas(&initial, Role::Decode).max(1);
        let mut timeline = Timeline::new(&initial.agent, trace_name, backend, cfg.window_s);
        timeline.events.push(TimelineEvent::Plan {
            t: 0.0,
            seq: 0,
            plan: initial.clone(),
        });
        // The scored retarget floors every group at one replica, so a
        // role scaler must never target below its group count —
        // otherwise its `current` drifts under the deployed total
        // during a lull and the next real scale-up is swallowed by an
        // empty diff (and Decision records misreport the fleet).
        let scaler_cfg = |role: Role| -> AutoscalerConfig {
            let groups = initial.pipelines.iter().filter(|p| p.role == role).count() as u32;
            let mut c = cfg.autoscale.clone();
            c.min_pipelines = c.min_pipelines.max(groups.max(1));
            // The floor wins over a max configured below the group
            // count — the fleet physically cannot shrink past one
            // replica per bound class.
            c.max_pipelines = c.max_pipelines.max(c.min_pipelines);
            c
        };
        Ok(Orchestrator {
            prefill_scaler: Autoscaler::new(scaler_cfg(Role::Prefill), pre0),
            decode_scaler: Autoscaler::new(scaler_cfg(Role::Decode), dec0),
            group_scaler: GroupScaler::new(cfg.autoscale.clone()),
            host_scaler: cfg
                .cpu_autoscale
                .clone()
                .map(|c| Autoscaler::new(c, initial.cpu_workers.max(1))),
            cfg,
            metrics: Arc::new(MetricsRegistry::new()),
            current: initial,
            planner: None,
            timeline,
            plan_seq: 0,
        })
    }

    /// Attach the slow-path planner: scale decisions then invoke
    /// `Planner::plan` on the agent graph to emit the fresh plan
    /// (falling back to structural retargeting when the planner's
    /// class layout would strand in-flight work).
    pub fn with_planner(mut self, planner: Planner, graph: Graph) -> Orchestrator {
        self.planner = Some((planner, graph));
        self
    }

    /// The plan currently considered live.
    pub fn current(&self) -> &ExecutionPlan {
        &self.current
    }

    /// Pressure signal for one role: device-time utilization, floored
    /// by normalized queue backlog so saturation shows before busy-time
    /// integrates.
    fn pressure(&self, util: f64, queue: usize, role: Role) -> f64 {
        pressure_signal(
            util,
            queue,
            role_capacity(&self.current, role) * self.cfg.backlog_factor,
        )
    }

    /// Ingest one window of observations; returns the plan change to
    /// apply, if any decision fired.
    pub fn observe_window(&mut self, w: &WindowStats) -> Result<Option<PlanChange>> {
        self.metrics.counter("orch_windows").inc();
        self.metrics.gauge("orch_prefill_util").set(w.prefill_util);
        self.metrics.gauge("orch_decode_util").set(w.decode_util);
        self.metrics.gauge("orch_host_util").set(w.host_util);
        self.metrics.gauge("orch_sla_attained").set(w.sla_attained);
        // Prefix-cache hit rate per group, when reuse traffic exists —
        // observed as a scaling signal alongside utilization: a
        // high-hit prefill group sustains more admitted work per
        // replica than its raw util suggests. Zero traffic (reuse off)
        // writes nothing, leaving pre-reuse behavior untouched.
        for g in &w.groups {
            let total = g.prefix_hits + g.prefix_misses;
            if total > 0 {
                self.metrics
                    .gauge(&format!("orch_group_prefix_hit_rate:{}", g.key))
                    .set(g.prefix_hits as f64 / total as f64);
            }
        }
        self.timeline.events.push(TimelineEvent::Window {
            t0: w.t0,
            t1: w.t1,
            arrivals: w.arrivals as u64,
            completed: w.completed as u64,
            sla_attained: w.sla_attained,
            prefill_util: w.prefill_util,
            decode_util: w.decode_util,
            // Filled post-run from the traced spans (if any): spans of
            // in-flight requests are only complete once the run drains.
            attribution: None,
        });

        let pre_pressure = self.pressure(w.prefill_util, w.prefill_queue, Role::Prefill);
        let dec_pressure = self.pressure(w.decode_util, w.decode_queue, Role::Decode);
        let d_pre = self.prefill_scaler.observe(pre_pressure);
        let d_dec = self.decode_scaler.observe(dec_pressure);
        // The cpu_workers autoscaler consumes the measured host-pool
        // utilization directly (tool/IO stages have no queue signal
        // here; worker busy-time is the pressure).
        let d_host = match self.host_scaler.as_mut() {
            Some(s) => s.observe(w.host_util),
            None => ScaleDecision::Hold,
        };
        let host_workers = self.host_scaler.as_ref().map(|s| s.current).unwrap_or(0);
        let pre_group = self.delta_group(Role::Prefill, d_pre);
        let dec_group = self.delta_group(Role::Decode, d_dec);
        for (role, decision, replicas, group) in [
            (Role::Prefill.name(), d_pre, self.prefill_scaler.current, pre_group),
            (Role::Decode.name(), d_dec, self.decode_scaler.current, dec_group),
            ("cpu", d_host, host_workers, None),
        ] {
            let (action, amount) = match decision {
                ScaleDecision::ScaleUp(n) => ("scale_up", n),
                ScaleDecision::ScaleDown(n) => ("scale_down", n),
                ScaleDecision::Hold => continue,
            };
            self.metrics.counter("orch_decisions").inc();
            self.timeline.events.push(TimelineEvent::Decision {
                t: w.t1,
                role: role.to_string(),
                action: action.to_string(),
                amount,
                replicas,
                group,
            });
        }

        // Per-group streaks over the executor's group signals: a group
        // persistently hot while a sibling of the same role idles is a
        // *rebalance*, not a resize — replicas move from the idle
        // worst-TCO group to the hot one, role total unchanged. Only
        // when the role scaler holds: a firing role scaler already
        // redistributes through the scored retarget.
        let rebalances = self.plan_rebalances(w, d_pre, d_dec);

        if d_pre == ScaleDecision::Hold
            && d_dec == ScaleDecision::Hold
            && d_host == ScaleDecision::Hold
            && rebalances.is_empty()
        {
            return Ok(None);
        }

        let (target, rejections, applied_rebalances) = self.emit_target(&rebalances)?;
        // Record only the rebalances that actually moved replicas — a
        // requested move whose keys a planner-fresh layout doesn't
        // carry is dropped, not logged.
        for rb in &applied_rebalances {
            self.metrics.counter("orch_rebalances").inc();
            let total = match rb.role {
                Role::Prefill => self.prefill_scaler.current,
                Role::Decode => self.decode_scaler.current,
            };
            for (action, group) in [
                ("rebalance_out", rb.from.clone()),
                ("rebalance_in", rb.to.clone()),
            ] {
                self.metrics.counter("orch_decisions").inc();
                self.timeline.events.push(TimelineEvent::Decision {
                    t: w.t1,
                    role: rb.role.name().to_string(),
                    action: action.to_string(),
                    amount: rb.amount,
                    replicas: total,
                    group: Some(group),
                });
            }
        }
        for r in &rejections {
            self.metrics.counter("orch_rejections").inc();
            self.timeline.events.push(TimelineEvent::Rejection {
                t: w.t1,
                role: r.role.clone(),
                group: r.group.clone(),
                reason: r.reason.clone(),
            });
        }
        // Static pre-flight: every re-plan candidate runs the full
        // analyzer pass stack before any migration is lowered. An
        // Error-severity finding rejects the candidate (typed, on the
        // timeline) and the fleet keeps its current plan.
        if !self.preflight(&target, w.t1).is_empty() {
            return Ok(None);
        }
        self.adopt(target, w.t1, w.kv_resident_bytes, rejections)
    }

    /// Static pre-flight over a re-plan candidate: run the analyzer's
    /// pass stack ([`verify::verify`]) and convert every Error-severity
    /// diagnostic into a typed [`PlanRejection`] plus a
    /// [`TimelineEvent::Rejection`]. Infeasible candidates are stopped
    /// *here* — before migration lowering touches them.
    fn preflight(&mut self, target: &ExecutionPlan, t: f64) -> Vec<PlanRejection> {
        let report = verify::verify(target);
        let mut rejections = Vec::new();
        for d in report.errors() {
            self.metrics.counter("orch_rejections").inc();
            let r = PlanRejection {
                role: "plan".to_string(),
                group: None,
                reason: format!("static analysis {} at {}: {}", d.code, d.loc, d.message),
            };
            self.timeline.events.push(TimelineEvent::Rejection {
                t,
                role: r.role.clone(),
                group: r.group.clone(),
                reason: r.reason.clone(),
            });
            rejections.push(r);
        }
        rejections
    }

    /// Offer an externally-built plan candidate to the loop at time
    /// `t`. The candidate runs the same static pre-flight as
    /// `observe_window` targets; Error-severity findings reject it
    /// (returned typed and recorded on the timeline) before any
    /// migration is lowered. A clean candidate is adopted exactly like
    /// a loop decision: diffed against the live plan, lowered to a
    /// capacity-safe migration, and recorded.
    pub fn propose_plan(
        &mut self,
        target: ExecutionPlan,
        t: f64,
        kv_resident_bytes: f64,
    ) -> Result<(Option<PlanChange>, Vec<PlanRejection>)> {
        let rejections = self.preflight(&target, t);
        if !rejections.is_empty() {
            return Ok((None, rejections));
        }
        let change = self.adopt(target, t, kv_resident_bytes, Vec::new())?;
        Ok((change, Vec::new()))
    }

    /// Adopt a pre-flighted target: diff it against the live plan,
    /// lower the capacity-safe migration, record the
    /// plan/diff/migration events, and flip `current`.
    fn adopt(
        &mut self,
        target: ExecutionPlan,
        t: f64,
        kv_resident_bytes: f64,
        rejections: Vec<PlanRejection>,
    ) -> Result<Option<PlanChange>> {
        let diff = PlanDiff::between(&self.current, &target);
        if diff.is_empty() {
            return Ok(None);
        }
        let migration = lower_diff(&self.current, &target, kv_resident_bytes)?;
        self.plan_seq += 1;
        self.metrics.counter("orch_migrations").inc();
        self.timeline.events.push(TimelineEvent::Plan {
            t,
            seq: self.plan_seq,
            plan: target.clone(),
        });
        self.timeline.events.push(TimelineEvent::Diff {
            t,
            diff: diff.clone(),
        });
        self.timeline.events.push(TimelineEvent::Migration {
            t,
            plan: migration.clone(),
            applied_s: None,
        });
        self.current = target.clone();
        Ok(Some(PlanChange {
            target,
            diff,
            migration,
            rejections,
        }))
    }

    /// Which group a role scaler's delta lands on *first* (for the
    /// decision record): growth buys the cheapest $/throughput group;
    /// shrinkage starts at the worst-TCO group **that still has
    /// replicas above its one-replica floor** — the same ranking and
    /// floor rule `retarget`'s scored distribution uses. A shrink
    /// larger than that group's spare replicas spills into the
    /// next-worst groups (the diff records the full spread); `None`
    /// when every group already sits at its floor and nothing will
    /// drain.
    fn delta_group(&self, role: Role, decision: ScaleDecision) -> Option<String> {
        let scores = score_groups(&self.current, role);
        match decision {
            ScaleDecision::ScaleUp(_) => cheapest(&scores).map(|s| s.key.clone()),
            ScaleDecision::ScaleDown(_) => {
                let drainable: Vec<_> = scores
                    .iter()
                    .filter(|s| self.current.pipelines[s.group].replicas > 1)
                    .cloned()
                    .collect();
                worst(&drainable).map(|s| s.key.clone())
            }
            ScaleDecision::Hold => None,
        }
    }

    /// Detect cross-group imbalance from the window's per-group
    /// signals. For each role whose total is holding: if a group's
    /// pressure streak fired hot while a sibling group sits at/below
    /// the low watermark with spare replicas, move replicas from the
    /// idle group to the hot one — preferring to *retire* the
    /// worst-TCO idle capacity and *grow* the cheapest hot group, the
    /// paper's mixed-fleet economics.
    fn plan_rebalances(
        &mut self,
        w: &WindowStats,
        d_pre: ScaleDecision,
        d_dec: ScaleDecision,
    ) -> Vec<PendingRebalance> {
        if w.groups.is_empty() {
            return Vec::new();
        }
        // Pressure per group: the shared rule against the group's own
        // batch capacity. (`backlog_factor` copied out so the closure
        // holds no `self` borrow — `group_scaler.observe` below needs
        // `self` mutably.)
        let backlog_factor = self.cfg.backlog_factor;
        let pressure_of = move |g: &GroupWindow| -> f64 {
            let cap = (g.replicas.max(1) as u64 * g.max_batch) as f64 * backlog_factor;
            pressure_signal(g.util, g.queue, cap)
        };
        let pressures: Vec<(String, f64)> =
            w.groups.iter().map(|g| (g.key.clone(), pressure_of(g))).collect();
        let fired = self.group_scaler.observe(&pressures);

        let mut out = Vec::new();
        for (role, decision) in [(Role::Prefill, d_pre), (Role::Decode, d_dec)] {
            if decision != ScaleDecision::Hold {
                continue; // the scored retarget already moves this role
            }
            let scores = score_groups(&self.current, role);
            if scores.len() < 2 {
                continue;
            }
            // Receiver: a group whose hot streak fired *this* window
            // (edge), cheapest first on ties.
            let hot: Option<&GroupScore> = fired
                .iter()
                .filter(|f| f.hot)
                .filter_map(|f| scores.iter().find(|s| s.key == f.key))
                .min_by(|a, b| rank(a, b));
            let Some(hot) = hot else { continue };
            // Donor: a sibling that has *sustained* its cold streak
            // (level — so an offset between the two crossings cannot
            // starve the pairing), with spare replicas; the worst-TCO
            // generation gives its capacity up first.
            let cold: Option<&GroupScore> = scores
                .iter()
                .filter(|s| s.key != hot.key)
                .filter(|s| self.group_scaler.sustained_cold(&s.key))
                .filter(|s| self.current.pipelines[s.group].replicas > 1)
                .max_by(|a, b| rank(a, b));
            let Some(cold) = cold else { continue };
            let spare = self.current.pipelines[cold.group].replicas.saturating_sub(1);
            let amount = ((spare as f64 * 0.5).ceil() as u32).clamp(1, spare);
            out.push(PendingRebalance {
                role,
                from: cold.key.clone(),
                to: hot.key.clone(),
                amount,
            });
        }
        out
    }

    /// Produce the next target plan at the autoscalers' replica totals:
    /// a fresh slow-path plan when a planner is attached (and its class
    /// layout stays compatible with in-flight work — incompatible
    /// re-plans are rejected with a typed reason, not dropped), else a
    /// structural retarget of the live plan. The role deltas distribute
    /// across pipeline groups by TCO score and pending cross-group
    /// rebalances apply on top (returning the subset that actually
    /// moved replicas, so the decision record never claims a move a
    /// foreign group layout swallowed). Sibling token fractions
    /// re-align with per-class capacity **only when the fleet itself
    /// changed** — a policy-only emit (e.g. a cpu_workers resize) must
    /// not overwrite a planner-chosen split. The cpu_workers scaler's
    /// worker total rides along.
    fn emit_target(
        &self,
        rebalances: &[PendingRebalance],
    ) -> Result<(ExecutionPlan, Vec<PlanRejection>, Vec<PendingRebalance>)> {
        let (base, rejections) = match &self.planner {
            Some((planner, graph)) => {
                let fresh = planner.plan(graph)?;
                reconcile_replan(&self.current, fresh)
            }
            None => (self.current.clone(), Vec::new()),
        };
        let mut target = retarget(
            &base,
            self.prefill_scaler.current,
            self.decode_scaler.current,
        );
        let mut applied = Vec::new();
        for rb in rebalances {
            let moved = rebalance(&target, rb.role, &rb.from, &rb.to, rb.amount);
            if moved.pipelines != target.pipelines {
                applied.push(rb.clone());
            }
            target = moved;
        }
        if target.pipelines != base.pipelines {
            target = retune_token_fractions(&target);
        }
        if let Some(s) = &self.host_scaler {
            target.cpu_workers = s.current.max(1);
        }
        target.validate()?;
        Ok((target, rejections, applied))
    }

    /// Executor callback: the most recent migration finished applying.
    pub fn record_applied(&mut self, t: f64, fc: &FleetChangeStats) {
        if let Some(TimelineEvent::Migration { applied_s, .. }) = self
            .timeline
            .events
            .iter_mut()
            .rev()
            .find(|e| matches!(e, TimelineEvent::Migration { .. }))
        {
            *applied_s = Some((fc.done_s - t).max(0.0));
        }
    }

    /// Close the loop: append the end-of-run summary and hand back the
    /// replayable timeline.
    pub fn finish(mut self, report: Option<&SimReport>) -> Timeline {
        if let Some(r) = report {
            self.timeline.events.push(TimelineEvent::Summary {
                t: r.makespan_s,
                requests: r.n_requests as u64,
                output_tokens: r.output_tokens,
                makespan_s: r.makespan_s,
            });
        }
        self.timeline
    }
}

/// Fill each recorded window's `attribution` from a traced run's spans
/// (windows match by their recorded `[t0, t1)` bounds; requests are
/// assigned by completion time) and export the whole-run critical-path
/// totals as `orch_attr_<bucket>_s` gauges plus `orch_attr_coverage` —
/// the measured "where did the latency go" signal next to the
/// utilization gauges the autoscalers consume.
pub fn attach_window_attribution(
    timeline: &mut Timeline,
    spans: &[Span],
    metrics: &MetricsRegistry,
) {
    let windows: Vec<(f64, f64)> = timeline
        .events
        .iter()
        .filter_map(|e| match e {
            TimelineEvent::Window { t0, t1, .. } => Some((*t0, *t1)),
            _ => None,
        })
        .collect();
    let mut attrs = attribute_windows(spans, &windows).into_iter();
    for e in &mut timeline.events {
        if let TimelineEvent::Window { attribution, .. } = e {
            *attribution = attrs.next();
        }
    }
    let total = attribute_all(spans);
    for b in BUCKETS {
        metrics
            .gauge(&format!("orch_attr_{b}_s"))
            .set(total.bucket_s(b));
    }
    metrics.gauge("orch_attr_coverage").set(total.coverage);
}

/// One interface, two backends: drive a workload to completion under
/// orchestrator control and return the recorded timeline.
pub trait Executor {
    /// Backend label (lands in the timeline).
    fn kind(&self) -> &'static str;

    /// Consume the orchestrator, run the workload, return the timeline.
    fn orchestrate(&mut self, orch: Orchestrator) -> Result<Timeline>;
}

// ---------------------------------------------------------------------
// Simulation backend
// ---------------------------------------------------------------------

/// Evaluate orchestration policies end-to-end in the DAG simulator:
/// the orchestrator's plan changes become live fleet changes (drains,
/// activations, KV migrations over the fabric) mid-run.
pub struct SimExecutor<'a> {
    pub trace: &'a [Request],
    /// Streaming source (constant-memory ingestion): when set, the run
    /// pulls arrivals lazily from it and `trace` is ignored.
    stream: Option<Box<dyn ArrivalProcess + 'a>>,
    /// Aggregate serving metrics of the finished run.
    pub report: Option<SimReport>,
    /// When set, the simulator records [`Span`]s into it and the
    /// returned timeline's windows carry critical-path attribution.
    pub trace_sink: Option<Arc<TraceSink>>,
}

impl<'a> SimExecutor<'a> {
    pub fn new(trace: &'a [Request]) -> SimExecutor<'a> {
        SimExecutor {
            trace,
            stream: None,
            report: None,
            trace_sink: None,
        }
    }

    /// Drive the orchestrated simulation from a streaming arrival
    /// process instead of a materialized slice — the whole run then
    /// holds memory bounded by the in-flight set, not the trace length.
    pub fn from_stream(arrivals: Box<dyn ArrivalProcess + 'a>) -> SimExecutor<'a> {
        SimExecutor {
            trace: &[],
            stream: Some(arrivals),
            report: None,
            trace_sink: None,
        }
    }
}

/// Adapter: the orchestrator as a [`FleetController`].
struct OrchController {
    orch: Orchestrator,
    failed: Option<Error>,
}

impl FleetController for OrchController {
    fn on_window(&mut self, stats: &WindowStats) -> Option<ExecutionPlan> {
        if self.failed.is_some() {
            return None;
        }
        match self.orch.observe_window(stats) {
            Ok(Some(change)) => Some(change.target),
            Ok(None) => None,
            Err(e) => {
                self.failed = Some(e);
                None
            }
        }
    }

    fn on_applied(&mut self, t: f64, stats: &FleetChangeStats) {
        self.orch.record_applied(t, stats);
    }
}

impl Executor for SimExecutor<'_> {
    fn kind(&self) -> &'static str {
        "sim"
    }

    fn orchestrate(&mut self, orch: Orchestrator) -> Result<Timeline> {
        let window_s = orch.cfg.window_s;
        let mut sim = DagSim::new(orch.current())?;
        if let Some(sink) = &self.trace_sink {
            sim.set_trace_sink(Arc::clone(sink));
        }
        let mut ctl = OrchController { orch, failed: None };
        let report = match self.stream.as_mut() {
            Some(s) => sim.run_stream_controlled(s.as_mut(), window_s, &mut ctl)?,
            None => sim.run_controlled(self.trace, window_s, &mut ctl)?,
        };
        if let Some(e) = ctl.failed {
            return Err(e);
        }
        let metrics = Arc::clone(&ctl.orch.metrics);
        let mut timeline = ctl.orch.finish(Some(&report));
        if let Some(sink) = &self.trace_sink {
            attach_window_attribution(&mut timeline, &sink.spans(), &metrics);
        }
        self.report = Some(report);
        Ok(timeline)
    }
}

// ---------------------------------------------------------------------
// Live backend
// ---------------------------------------------------------------------

/// Reconfigure a running [`Server`] between request windows. The
/// per-role pressure signal is **measured**: the server times engine
/// prefill/decode execution and the host pool accumulates worker
/// busy-time, so the orchestrator observes the same quantities here as
/// it does from the DAG simulator (`Server::take_utilization`), not the
/// old SLA-headroom proxy. SLA attainment is still tracked from
/// response latencies against the plan envelope.
pub struct LiveExecutor {
    pub server: Server,
    pub requests: Vec<ChatRequest>,
    /// Streaming source: when set, request windows are drawn lazily
    /// from it (up to the paired cap) and `requests` is ignored — only
    /// one window of [`ChatRequest`]s is materialized at a time.
    stream: Option<(Box<dyn ArrivalProcess>, usize)>,
    /// Requests per observation window.
    pub window: usize,
    /// When set, the server records [`Span`]s into it and the returned
    /// timeline's windows carry critical-path attribution. Each `serve`
    /// session stamps span times from its own origin, so live windows
    /// attribute the spans recorded *during* them (a cursor over the
    /// sink) instead of bucketing by timestamp.
    pub trace_sink: Option<Arc<TraceSink>>,
}

/// Lower a simulator [`Request`] to a live [`ChatRequest`]: a
/// deterministic printable payload of the request's prompt length
/// (clamped so live runs stay tractable) and its OSL as the generation
/// cap. Both backends then see the same per-request shape, which is
/// what the sim/live conformance suite compares.
pub fn chat_request_of(r: &Request) -> ChatRequest {
    let payload = vec![b'a' + (r.id % 23) as u8; r.isl.clamp(1, 2048) as usize];
    ChatRequest::new(r.id, payload, r.osl.max(1) as usize)
}

impl LiveExecutor {
    pub fn new(server: Server, requests: Vec<ChatRequest>, window: usize) -> LiveExecutor {
        LiveExecutor {
            server,
            requests,
            stream: None,
            window: window.max(1),
            trace_sink: None,
        }
    }

    /// Window live serving over a streaming arrival process: at most
    /// `max_requests` are drawn (live arrival processes are typically
    /// unbounded), one window's worth materialized at a time via
    /// [`chat_request_of`].
    pub fn from_stream(
        server: Server,
        arrivals: Box<dyn ArrivalProcess>,
        window: usize,
        max_requests: usize,
    ) -> LiveExecutor {
        LiveExecutor {
            server,
            requests: Vec::new(),
            stream: Some((arrivals, max_requests)),
            window: window.max(1),
            trace_sink: None,
        }
    }
}

impl Executor for LiveExecutor {
    fn kind(&self) -> &'static str {
        "live"
    }

    fn orchestrate(&mut self, mut orch: Orchestrator) -> Result<Timeline> {
        let sla_s = match orch.current().sla {
            SlaSpec::EndToEnd(t) => Some(t),
            SlaSpec::Soft { t_sla_s, .. } => Some(t_sla_s),
            SlaSpec::None => None,
        };
        if let Some(sink) = &self.trace_sink {
            self.server.set_trace_sink(Arc::clone(sink));
        }
        // Per-window attribution over the spans each window recorded
        // (see `trace_sink` docs), attached to the timeline post-run.
        let mut window_attrs: Vec<SlaAttribution> = Vec::new();
        let mut spans_seen = 0usize;
        // Rolling snapshots of the server's cumulative per-group prefix
        // counters, so each window reports deltas (the simulator's
        // window_stats applies the same rule).
        let mut prev_prefix: std::collections::BTreeMap<String, (u64, u64)> =
            std::collections::BTreeMap::new();
        let requests = std::mem::take(&mut self.requests);
        // Either source yields windows; the streaming one materializes
        // a single window of ChatRequests at a time.
        let mut source: Box<dyn Iterator<Item = ChatRequest>> = match self.stream.take() {
            Some((s, max)) => Box::new(s.take(max).map(|r| chat_request_of(&r))),
            None => Box::new(requests.into_iter()),
        };
        let mut t = 0.0f64;
        loop {
            let chunk: Vec<ChatRequest> = source.by_ref().take(self.window).collect();
            if chunk.is_empty() {
                break;
            }
            // Apply the live plan before the window — reconfiguration
            // lands between requests, never under one. The full-plan
            // path also swaps the DAG execution structure + host-pool
            // sizing; servers that cannot host the plan's DAG (e.g. no
            // catalog model) still get the policy swap, with the
            // non-plan knobs (token cap, history, time scale)
            // preserved exactly as the success path preserves them.
            if self.server.reconfigure_plan(orch.current()).is_err() {
                let mut cfg = ServerConfig::from_plan(orch.current());
                let cur = self.server.config();
                cfg.max_new_tokens = cur.max_new_tokens;
                cfg.max_history = cur.max_history;
                cfg.time_scale = cur.time_scale;
                self.server.reconfigure(cfg);
            }
            let wall0 = std::time::Instant::now();
            let responses = self.server.run_workload(chunk.clone())?;
            let wall = wall0.elapsed().as_secs_f64().max(1e-6);

            let e2es: Vec<f64> = responses
                .iter()
                .filter(|r| r.is_ok())
                .map(|r| r.e2e_s)
                .collect();
            let completed = e2es.len();
            let ok = match sla_s {
                Some(s) => e2es.iter().filter(|&&e| e <= s).count(),
                None => completed,
            };
            // Per-engine measured utilization first (take_utilization
            // resets the window): each pool engine lands on its own
            // gauge, so a hot decode engine is visible even when the
            // role aggregate looks calm.
            for (i, (pre, dec)) in
                self.server.engine_utilization(wall).into_iter().enumerate()
            {
                orch.metrics
                    .gauge(&format!("orch_engine{i}_prefill_util"))
                    .set(pre);
                orch.metrics
                    .gauge(&format!("orch_engine{i}_decode_util"))
                    .set(dec);
            }
            // Per-group signals before take_utilization resets the
            // window: each plan group reads its engine's role half, so
            // the orchestrator sees which hardware generation is hot.
            let group_utils = self.server.group_utilization(wall);
            let groups: Vec<GroupWindow> = orch
                .current()
                .pipelines
                .iter()
                .enumerate()
                .map(|(g, p)| {
                    let key = p.shape_key();
                    let hits = self
                        .server
                        .metrics
                        .counter(&format!("server_prefix_hits:{key}"))
                        .get();
                    let misses = self
                        .server
                        .metrics
                        .counter(&format!("server_prefix_misses:{key}"))
                        .get();
                    let (ph, pm) = prev_prefix
                        .insert(key.clone(), (hits, misses))
                        .unwrap_or((0, 0));
                    GroupWindow {
                        role: p.role,
                        key,
                        device: p.device.clone(),
                        replicas: p.replicas,
                        max_batch: p.max_batch,
                        util: group_utils.get(g).copied().unwrap_or(0.0),
                        queue: 0,
                        prefix_hits: hits.saturating_sub(ph),
                        prefix_misses: misses.saturating_sub(pm),
                    }
                })
                .collect();
            let (prefill_util, decode_util, host_util) =
                self.server.take_utilization(wall);
            let stats = WindowStats {
                t0: t,
                t1: t + wall,
                arrivals: chunk.len(),
                completed,
                sla_attained: if completed == 0 {
                    1.0
                } else {
                    ok as f64 / completed as f64
                },
                prefill_util,
                decode_util,
                host_util,
                prefill_queue: 0,
                decode_queue: 0,
                decode_active: 0,
                kv_resident_bytes: 0.0,
                prefill_pipes: role_replicas(orch.current(), Role::Prefill),
                decode_pipes: role_replicas(orch.current(), Role::Decode),
                groups,
            };
            t += wall;
            if orch.observe_window(&stats)?.is_some() {
                // Live apply = policy swap at the next window boundary;
                // it completes immediately from the loop's perspective.
                let fc = FleetChangeStats {
                    t,
                    done_s: t,
                    ..Default::default()
                };
                orch.record_applied(t, &fc);
            }
            if let Some(sink) = &self.trace_sink {
                let all = sink.spans();
                let mut a = attribute_all(&all[spans_seen.min(all.len())..]);
                spans_seen = all.len();
                // Relabel with the recorded window bounds: span clocks
                // restart per serve session and cannot place windows.
                a.t0 = stats.t0;
                a.t1 = stats.t1;
                window_attrs.push(a);
            }
        }
        let metrics = Arc::clone(&orch.metrics);
        let mut timeline = orch.finish(None);
        if let Some(sink) = &self.trace_sink {
            let mut attrs = window_attrs.into_iter();
            for e in &mut timeline.events {
                if let TimelineEvent::Window { attribution, .. } = e {
                    *attribution = attrs.next();
                }
            }
            // Whole-run bucket totals: per-request walks are clock-
            // independent, so overlapping session clocks are fine here.
            let total = attribute_all(&sink.spans());
            for b in BUCKETS {
                metrics
                    .gauge(&format!("orch_attr_{b}_s"))
                    .set(total.bucket_s(b));
            }
            metrics.gauge("orch_attr_coverage").set(total.coverage);
        }
        Ok(timeline)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::plan::tests::tiny_plan;
    use std::collections::BTreeSet;

    fn stats(util: f64, t0: f64, t1: f64) -> WindowStats {
        WindowStats {
            t0,
            t1,
            arrivals: 10,
            completed: 10,
            sla_attained: 1.0,
            prefill_util: util,
            decode_util: util,
            host_util: 0.0,
            prefill_queue: 0,
            decode_queue: 0,
            decode_active: 0,
            kv_resident_bytes: 4e9,
            prefill_pipes: 1,
            decode_pipes: 2,
            groups: Vec::new(),
        }
    }

    fn quick_cfg() -> OrchestratorConfig {
        OrchestratorConfig {
            window_s: 1.0,
            autoscale: AutoscalerConfig {
                patience: 2,
                ..Default::default()
            },
            backlog_factor: 1.0,
            cpu_autoscale: None,
        }
    }

    #[test]
    fn sustained_pressure_emits_plan_diff_migration() {
        let mut orch =
            Orchestrator::new(quick_cfg(), tiny_plan(), "synthetic", "test").unwrap();
        assert!(orch.observe_window(&stats(0.95, 0.0, 1.0)).unwrap().is_none());
        let change = orch
            .observe_window(&stats(0.95, 1.0, 2.0))
            .unwrap()
            .expect("patience=2 must fire on the second hot window");
        // Decode grew; the diff and migration agree with the target.
        assert!(role_replicas(&change.target, Role::Decode) > 2);
        assert!(!change.diff.is_empty());
        assert!(!change.migration.steps.is_empty());
        assert_eq!(orch.current(), &change.target);
        // Admission followed capacity up.
        assert!(change.target.admission.rate > tiny_plan().admission.rate);

        let tl = orch.finish(None);
        assert_eq!(tl.n_plans(), 2);
        assert_eq!(tl.n_migrations(), 1);
        assert!(tl.n_decisions() >= 1);
    }

    #[test]
    fn idle_windows_scale_back_down() {
        let mut orch =
            Orchestrator::new(quick_cfg(), tiny_plan(), "synthetic", "test").unwrap();
        // Scale up first...
        orch.observe_window(&stats(0.95, 0.0, 1.0)).unwrap();
        let up = orch.observe_window(&stats(0.95, 1.0, 2.0)).unwrap().unwrap();
        let grown = role_replicas(&up.target, Role::Decode);
        // ...then two idle windows shrink the fleet.
        orch.observe_window(&stats(0.05, 2.0, 3.0)).unwrap();
        let down = orch
            .observe_window(&stats(0.05, 3.0, 4.0))
            .unwrap()
            .expect("idle patience must trigger scale-down");
        assert!(role_replicas(&down.target, Role::Decode) < grown);
        // The shrink migration drains pipelines and moves their KV share.
        assert!(down
            .migration
            .steps
            .iter()
            .any(|s| matches!(s, crate::planner::MigrationStep::Drain { .. })));
        assert!(down.migration.kv_bytes > 0.0);
    }

    #[test]
    fn host_pressure_resizes_cpu_workers() {
        let mut cfg = quick_cfg();
        cfg.cpu_autoscale = Some(AutoscalerConfig {
            patience: 2,
            min_pipelines: 1,
            max_pipelines: 512,
            ..Default::default()
        });
        let mut orch =
            Orchestrator::new(cfg, tiny_plan(), "synthetic", "test").unwrap();
        // Mid-band pre/dec utilization holds the pipeline fleet still;
        // only the host pool is under pressure.
        let host = |util: f64, t0: f64, t1: f64| {
            let mut w = stats(0.5, t0, t1);
            w.host_util = util;
            w
        };
        assert!(orch.observe_window(&host(0.95, 0.0, 1.0)).unwrap().is_none());
        let up = orch
            .observe_window(&host(0.95, 1.0, 2.0))
            .unwrap()
            .expect("host patience=2 must fire");
        assert!(
            up.target.cpu_workers > 64,
            "cpu_workers must grow: {}",
            up.target.cpu_workers
        );
        assert!(
            up.diff.policy.iter().any(|p| p.field == "cpu_workers"),
            "the diff must type the host-pool resize: {}",
            up.diff.summary()
        );
        assert!(
            up.migration.steps.is_empty(),
            "a pure host-pool resize moves no pipelines"
        );
        assert!(up.rejections.is_empty());
        let grown = up.target.cpu_workers;
        // Two idle host windows shrink the pool back.
        orch.observe_window(&host(0.05, 2.0, 3.0)).unwrap();
        let down = orch
            .observe_window(&host(0.05, 3.0, 4.0))
            .unwrap()
            .expect("idle host windows must scale the pool down");
        assert!(down.target.cpu_workers < grown);
    }

    #[test]
    fn incompatible_replan_is_rejected_with_typed_reason() {
        let current = tiny_plan(); // decode on Gaudi3
        let mut fresh = tiny_plan();
        fresh.pipelines[1].device = "H100".into();
        fresh.bindings[2].class = "H100".into();
        let (kept, rejections) = reconcile_replan(&current, fresh);
        assert_eq!(kept, current, "incompatible layouts keep the live plan");
        assert_eq!(rejections.len(), 1);
        assert_eq!(rejections[0].role, "decode");
        assert_eq!(
            rejections[0].group.as_deref(),
            Some("decode Gaudi3 tp1 pp1 b32"),
            "the rejection names the live group it kept"
        );
        assert!(
            rejections[0].reason.contains("Gaudi3"),
            "{}",
            rejections[0].reason
        );
        // Compatible layouts (same classes, different replica counts)
        // pass through untouched.
        let mut resized = tiny_plan();
        resized.pipelines[1].replicas = 5;
        let (adopted, rej) = reconcile_replan(&current, resized.clone());
        assert_eq!(adopted, resized);
        assert!(rej.is_empty());
    }

    #[test]
    fn backlog_counts_as_pressure_even_at_low_utilization() {
        let mut orch =
            Orchestrator::new(quick_cfg(), tiny_plan(), "synthetic", "test").unwrap();
        let mut w = stats(0.1, 0.0, 1.0);
        w.decode_queue = 10_000; // >> 2 pipes × batch 32
        assert!(orch.observe_window(&w).unwrap().is_none());
        let mut w2 = stats(0.1, 1.0, 2.0);
        w2.decode_queue = 10_000;
        let change = orch.observe_window(&w2).unwrap();
        assert!(change.is_some(), "backlog alone must trigger scale-up");
    }

    #[test]
    fn hot_and_cold_groups_trigger_a_pure_cross_group_rebalance() {
        use crate::plan::presets::mixed_generation;

        // A100 decode capacity idles while the H100 group runs hot and
        // the role aggregate stays mid-band: nothing for the role
        // scaler, everything for the rebalancer.
        let plan = mixed_generation("8b-fp16", "H100", "A100", 1, 3);
        let hot_key = plan.pipelines[1].shape_key(); // decode H100 ×1
        let cold_key = plan.pipelines[2].shape_key(); // decode A100 ×3
        let mut orch =
            Orchestrator::new(quick_cfg(), plan.clone(), "synthetic", "test").unwrap();
        let window = |t0: f64, t1: f64| {
            let mut w = stats(0.5, t0, t1); // aggregate mid-band: role holds
            w.groups = plan
                .pipelines
                .iter()
                .map(|p| GroupWindow {
                    role: p.role,
                    key: p.shape_key(),
                    device: p.device.clone(),
                    replicas: p.replicas,
                    max_batch: p.max_batch,
                    util: if p.shape_key() == hot_key {
                        0.97
                    } else if p.shape_key() == cold_key {
                        0.05
                    } else {
                        0.5
                    },
                    queue: 0,
                    prefix_hits: 0,
                    prefix_misses: 0,
                })
                .collect();
            w
        };
        assert!(orch.observe_window(&window(0.0, 1.0)).unwrap().is_none());
        let change = orch
            .observe_window(&window(1.0, 2.0))
            .unwrap()
            .expect("patience=2 group streaks must fire a rebalance");
        // Role total unchanged; replicas moved cold → hot.
        assert_eq!(role_replicas(&change.target, Role::Decode), 4);
        let by_key = |p: &ExecutionPlan, key: &str| -> u32 {
            p.pipelines
                .iter()
                .find(|g| g.shape_key() == key)
                .map(|g| g.replicas)
                .unwrap_or(0)
        };
        assert_eq!(by_key(&change.target, &hot_key), 2, "{}", change.diff.summary());
        assert_eq!(by_key(&change.target, &cold_key), 2);
        assert!(change.diff.is_cross_group(), "{}", change.diff.summary());
        // The load follows the hardware: sibling fractions re-aligned
        // to the new 50/50 capacity split.
        assert!(
            change.diff.retuned.len() == 2,
            "fraction shift must be typed: {}",
            change.diff.summary()
        );
        assert!((change.target.bindings[2].token_fraction - 0.5).abs() < 1e-9);
        // The decision trail names both groups.
        let tl = orch.finish(None);
        let actions: Vec<(String, Option<String>)> = tl
            .events
            .iter()
            .filter_map(|e| match e {
                TimelineEvent::Decision { action, group, .. } => {
                    Some((action.clone(), group.clone()))
                }
                _ => None,
            })
            .collect();
        assert!(actions.contains(&("rebalance_out".to_string(), Some(cold_key.clone()))));
        assert!(actions.contains(&("rebalance_in".to_string(), Some(hot_key.clone()))));
    }

    #[test]
    fn aggregate_pressure_on_mixed_fleet_scales_the_cheapest_group() {
        use crate::plan::presets::mixed_generation;
        use crate::planner::autoscale::score_groups;

        let plan = mixed_generation("8b-fp16", "H100", "A100", 2, 2);
        let scores = score_groups(&plan, Role::Decode);
        let cheapest_key = scores
            .iter()
            .min_by(|a, b| a.score.partial_cmp(&b.score).unwrap())
            .unwrap()
            .key
            .clone();
        let mut orch =
            Orchestrator::new(quick_cfg(), plan.clone(), "synthetic", "test").unwrap();
        orch.observe_window(&stats(0.95, 0.0, 1.0)).unwrap();
        let change = orch
            .observe_window(&stats(0.95, 1.0, 2.0))
            .unwrap()
            .expect("sustained pressure must fire");
        // The growth bought the cheapest generation's capacity only.
        let grew: Vec<&str> = change
            .diff
            .resized
            .iter()
            .filter(|r| r.role == Role::Decode && r.to_replicas > r.from_replicas)
            .map(|r| r.device.as_str())
            .collect();
        assert_eq!(grew.len(), 1, "{}", change.diff.summary());
        assert!(
            cheapest_key.contains(grew[0]),
            "growth must land on {cheapest_key}, grew {grew:?}"
        );
        // And the token split followed the capacity.
        assert!(!change.diff.retuned.is_empty(), "{}", change.diff.summary());
    }

    #[test]
    fn planner_backed_replan_keeps_compatible_classes() {
        use crate::agents;
        use crate::planner::plan::{Planner, PlannerConfig};

        let g = agents::voice_agent("8b-fp16", 512, 128);
        let mut pcfg = PlannerConfig::default();
        pcfg.sla = crate::opt::assignment::Sla::None;
        let planner = Planner::new(pcfg);
        let initial = planner.plan(&g).unwrap();
        let dec0 = role_replicas(&initial, Role::Decode);

        let pcfg2 = {
            let mut c = PlannerConfig::default();
            c.sla = crate::opt::assignment::Sla::None;
            c
        };
        let mut orch = Orchestrator::new(quick_cfg(), initial.clone(), "synthetic", "test")
            .unwrap()
            .with_planner(Planner::new(pcfg2), g);
        orch.observe_window(&stats(0.95, 0.0, 1.0)).unwrap();
        let change = orch
            .observe_window(&stats(0.95, 1.0, 2.0))
            .unwrap()
            .expect("hot windows must re-plan");
        // The planner-backed target serves the same classes, scaled up.
        assert!(role_replicas(&change.target, Role::Decode) > dec0);
        change.target.validate().unwrap();
        let old: BTreeSet<(Role, String)> = initial
            .pipelines
            .iter()
            .map(|p| (p.role, p.device.clone()))
            .collect();
        let new: BTreeSet<(Role, String)> = change
            .target
            .pipelines
            .iter()
            .map(|p| (p.role, p.device.clone()))
            .collect();
        assert_eq!(old, new);
    }
}
