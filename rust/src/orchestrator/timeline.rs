//! The replayable orchestration record: every window observation,
//! scaling decision, emitted plan, diff, and migration — in order,
//! serializable through [`crate::util::json`] so a run can be saved
//! (`orchestrate --out timeline.json`), reviewed, and replayed.

use crate::obs::critical_path::SlaAttribution;
use crate::plan::{ExecutionPlan, PlanDiff};
use crate::planner::migration::MigrationPlan;
use crate::util::json::Json;
use crate::{jobj, Error, Result};

/// One entry in the orchestration timeline.
#[derive(Debug, Clone, PartialEq)]
pub enum TimelineEvent {
    /// Window observation (see [`crate::cluster::dag::WindowStats`]).
    Window {
        t0: f64,
        t1: f64,
        arrivals: u64,
        completed: u64,
        sla_attained: f64,
        prefill_util: f64,
        decode_util: f64,
        /// Critical-path latency attribution for requests completing in
        /// this window — present only when the run traced spans
        /// (`--trace-out`); `None` otherwise, and records written
        /// before attribution existed parse that way.
        attribution: Option<SlaAttribution>,
    },
    /// A per-role autoscaler (or cross-group rebalance) fired.
    Decision {
        t: f64,
        role: String,
        /// "scale_up" | "scale_down" | "rebalance_out" | "rebalance_in"
        action: String,
        amount: u32,
        /// Role replica total after the decision.
        replicas: u32,
        /// Shape key of the pipeline group the decision targets; `None`
        /// = the role's primary group (pre-group-granular records).
        group: Option<String>,
    },
    /// A (re-)planned `ExecutionPlan` became the orchestration target.
    Plan {
        t: f64,
        /// 0 = the initial plan; increments per re-plan.
        seq: u64,
        plan: ExecutionPlan,
    },
    /// The typed diff connecting the previous plan to the new one.
    Diff { t: f64, diff: PlanDiff },
    /// A re-plan the loop refused to adopt mid-run (e.g. a structural
    /// retarget that would move a role's hardware classes under
    /// in-flight work) — the role affected and why, so rejected
    /// decisions leave a trace instead of silently vanishing. `group`
    /// is the shape key of the pipeline group the rejected change
    /// targeted; `None` = the role's primary group (records written
    /// before diffs became group-granular parse that way).
    Rejection {
        t: f64,
        role: String,
        group: Option<String>,
        reason: String,
    },
    /// The migration lowered from that diff.
    Migration {
        t: f64,
        plan: MigrationPlan,
        /// Observed apply duration, once the executor reports it.
        applied_s: Option<f64>,
    },
    /// End-of-run rollup.
    Summary {
        t: f64,
        requests: u64,
        output_tokens: u64,
        makespan_s: f64,
    },
}

/// A full orchestration run record.
#[derive(Debug, Clone, PartialEq)]
pub struct Timeline {
    pub agent: String,
    pub trace_name: String,
    pub backend: String,
    pub window_s: f64,
    pub events: Vec<TimelineEvent>,
}

impl Timeline {
    pub fn new(agent: &str, trace_name: &str, backend: &str, window_s: f64) -> Timeline {
        Timeline {
            agent: agent.to_string(),
            trace_name: trace_name.to_string(),
            backend: backend.to_string(),
            window_s,
            events: Vec::new(),
        }
    }

    /// Distinct plans emitted (including the initial one).
    pub fn n_plans(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, TimelineEvent::Plan { .. }))
            .count()
    }

    pub fn n_migrations(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, TimelineEvent::Migration { .. }))
            .count()
    }

    pub fn n_decisions(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, TimelineEvent::Decision { .. }))
            .count()
    }

    /// Re-plans the loop refused to adopt mid-run.
    pub fn n_rejections(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, TimelineEvent::Rejection { .. }))
            .count()
    }

    /// Diffs that moved capacity or load *between* pipeline groups (see
    /// [`PlanDiff::is_cross_group`]) — the heterogeneous-rebalance
    /// count the mixed-fleet demo reports.
    pub fn n_cross_group_rebalances(&self) -> usize {
        self.events
            .iter()
            .filter(|e| matches!(e, TimelineEvent::Diff { diff, .. } if diff.is_cross_group()))
            .count()
    }

    /// The emitted plans, in order.
    pub fn plans(&self) -> Vec<&ExecutionPlan> {
        self.events
            .iter()
            .filter_map(|e| match e {
                TimelineEvent::Plan { plan, .. } => Some(plan),
                _ => None,
            })
            .collect()
    }

    /// Completion-weighted SLA attainment across all windows (1.0 when
    /// nothing completed).
    pub fn sla_attainment(&self) -> f64 {
        let (mut done, mut ok) = (0.0f64, 0.0f64);
        for e in &self.events {
            if let TimelineEvent::Window {
                completed,
                sla_attained,
                ..
            } = e
            {
                done += *completed as f64;
                ok += *completed as f64 * sla_attained;
            }
        }
        if done > 0.0 {
            ok / done
        } else {
            1.0
        }
    }

    /// One-paragraph human rollup.
    pub fn summary(&self) -> String {
        let windows = self
            .events
            .iter()
            .filter(|e| matches!(e, TimelineEvent::Window { .. }))
            .count();
        format!(
            "orchestrated @{} over `{}` ({}): {} windows of {}s, {} decisions, \
             {} plans, {} migrations, SLA attainment {:.1}%",
            self.agent,
            self.trace_name,
            self.backend,
            windows,
            self.window_s,
            self.n_decisions(),
            self.n_plans(),
            self.n_migrations(),
            self.sla_attainment() * 100.0
        )
    }

    // ---- JSON round-trip -------------------------------------------

    pub fn to_json(&self) -> Json {
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| match e {
                TimelineEvent::Window {
                    t0,
                    t1,
                    arrivals,
                    completed,
                    sla_attained,
                    prefill_util,
                    decode_util,
                    attribution,
                } => {
                    let mut j = jobj! {
                        "kind" => "window",
                        "t0" => *t0,
                        "t1" => *t1,
                        "arrivals" => *arrivals,
                        "completed" => *completed,
                        "sla_attained" => *sla_attained,
                        "prefill_util" => *prefill_util,
                        "decode_util" => *decode_util,
                    };
                    // Written only when traced: untraced records stay
                    // byte-identical and old readers stay compatible.
                    if let Some(a) = attribution {
                        j.try_set("attribution", a.to_json())
                            .expect("window json is an object");
                    }
                    j
                }
                TimelineEvent::Decision {
                    t,
                    role,
                    action,
                    amount,
                    replicas,
                    group,
                } => {
                    let mut j = jobj! {
                        "kind" => "decision",
                        "t" => *t,
                        "role" => role.clone(),
                        "action" => action.clone(),
                        "amount" => *amount,
                        "replicas" => *replicas,
                    };
                    // Written only when set: pre-group records stay
                    // byte-identical and old readers stay compatible.
                    if let Some(g) = group {
                        j.try_set("group", g.clone()).expect("decision json is an object");
                    }
                    j
                }
                TimelineEvent::Plan { t, seq, plan } => jobj! {
                    "kind" => "plan",
                    "t" => *t,
                    "seq" => *seq,
                    "plan" => plan.to_json(),
                },
                TimelineEvent::Diff { t, diff } => jobj! {
                    "kind" => "diff",
                    "t" => *t,
                    "diff" => diff.to_json(),
                },
                TimelineEvent::Rejection {
                    t,
                    role,
                    group,
                    reason,
                } => {
                    let mut j = jobj! {
                        "kind" => "rejection",
                        "t" => *t,
                        "role" => role.clone(),
                        "reason" => reason.clone(),
                    };
                    if let Some(g) = group {
                        j.try_set("group", g.clone()).expect("rejection json is an object");
                    }
                    j
                }
                TimelineEvent::Migration { t, plan, applied_s } => {
                    let applied = match applied_s {
                        Some(v) => Json::Num(*v),
                        None => Json::Null,
                    };
                    jobj! {
                        "kind" => "migration",
                        "t" => *t,
                        "migration" => plan.to_json(),
                        "applied_s" => applied,
                    }
                }
                TimelineEvent::Summary {
                    t,
                    requests,
                    output_tokens,
                    makespan_s,
                } => jobj! {
                    "kind" => "summary",
                    "t" => *t,
                    "requests" => *requests,
                    "output_tokens" => *output_tokens,
                    "makespan_s" => *makespan_s,
                },
            })
            .collect();
        jobj! {
            "agent" => self.agent.clone(),
            "trace" => self.trace_name.clone(),
            "backend" => self.backend.clone(),
            "window_s" => self.window_s,
            "events" => Json::Arr(events),
        }
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().pretty()
    }

    pub fn parse_json(src: &str) -> Result<Timeline> {
        Self::from_json(&Json::parse(src)?)
    }

    pub fn from_json(j: &Json) -> Result<Timeline> {
        let str_of = |key: &str| -> Result<String> {
            j.get(key)
                .and_then(|v| v.as_str())
                .map(|s| s.to_string())
                .ok_or_else(|| Error::Config(format!("timeline missing `{key}`")))
        };
        let mut tl = Timeline {
            agent: str_of("agent")?,
            trace_name: str_of("trace")?,
            backend: str_of("backend")?,
            window_s: j
                .get("window_s")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| Error::Config("timeline missing `window_s`".into()))?,
            events: Vec::new(),
        };
        let events = j
            .get("events")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| Error::Config("timeline missing `events`".into()))?;
        for e in events {
            let num = |key: &str| -> Result<f64> {
                e.get(key).and_then(|v| v.as_f64()).ok_or_else(|| {
                    Error::Config(format!("timeline event missing `{key}`"))
                })
            };
            let int = |key: &str| -> Result<u64> {
                e.get(key).and_then(|v| v.as_u64()).ok_or_else(|| {
                    Error::Config(format!("timeline event missing `{key}`"))
                })
            };
            let text = |key: &str| -> Result<String> {
                e.get(key)
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string())
                    .ok_or_else(|| {
                        Error::Config(format!("timeline event missing `{key}`"))
                    })
            };
            let ev = match e.get("kind").and_then(|v| v.as_str()) {
                Some("window") => TimelineEvent::Window {
                    t0: num("t0")?,
                    t1: num("t1")?,
                    arrivals: int("arrivals")?,
                    completed: int("completed")?,
                    sla_attained: num("sla_attained")?,
                    prefill_util: num("prefill_util")?,
                    decode_util: num("decode_util")?,
                    // Back-compat: absent = the run was not traced.
                    attribution: match e.get("attribution") {
                        Some(a) => Some(SlaAttribution::from_json(a)?),
                        None => None,
                    },
                },
                Some("decision") => TimelineEvent::Decision {
                    t: num("t")?,
                    role: text("role")?,
                    action: text("action")?,
                    amount: int("amount")? as u32,
                    replicas: int("replicas")? as u32,
                    // Back-compat: absent = the role's primary group.
                    group: e
                        .get("group")
                        .and_then(|v| v.as_str())
                        .map(|s| s.to_string()),
                },
                Some("plan") => TimelineEvent::Plan {
                    t: num("t")?,
                    seq: int("seq")?,
                    plan: ExecutionPlan::from_json(e.get("plan").ok_or_else(|| {
                        Error::Config("plan event missing `plan`".into())
                    })?)?,
                },
                Some("diff") => TimelineEvent::Diff {
                    t: num("t")?,
                    diff: PlanDiff::from_json(e.get("diff").ok_or_else(|| {
                        Error::Config("diff event missing `diff`".into())
                    })?)?,
                },
                Some("rejection") => TimelineEvent::Rejection {
                    t: num("t")?,
                    role: text("role")?,
                    // Back-compat: absent = the role's primary group.
                    group: e
                        .get("group")
                        .and_then(|v| v.as_str())
                        .map(|s| s.to_string()),
                    reason: text("reason")?,
                },
                Some("migration") => TimelineEvent::Migration {
                    t: num("t")?,
                    plan: MigrationPlan::from_json(e.get("migration").ok_or_else(
                        || Error::Config("migration event missing `migration`".into()),
                    )?)?,
                    applied_s: e.get("applied_s").and_then(|v| v.as_f64()),
                },
                Some("summary") => TimelineEvent::Summary {
                    t: num("t")?,
                    requests: int("requests")?,
                    output_tokens: int("output_tokens")?,
                    makespan_s: num("makespan_s")?,
                },
                other => {
                    return Err(Error::Config(format!(
                        "unknown timeline event kind {other:?}"
                    )))
                }
            };
            tl.events.push(ev);
        }
        Ok(tl)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orchestrator::diff_apply::{lower_diff, retarget};
    use crate::plan::tests::tiny_plan;

    fn sample() -> Timeline {
        let a = tiny_plan();
        let b = retarget(&a, 1, 3);
        let mut tl = Timeline::new("tiny", "bursty", "sim", 2.0);
        tl.events.push(TimelineEvent::Plan {
            t: 0.0,
            seq: 0,
            plan: a.clone(),
        });
        tl.events.push(TimelineEvent::Window {
            t0: 0.0,
            t1: 2.0,
            arrivals: 10,
            completed: 8,
            sla_attained: 0.75,
            prefill_util: 0.4,
            decode_util: 0.9,
            attribution: None,
        });
        tl.events.push(TimelineEvent::Decision {
            t: 2.0,
            role: "decode".into(),
            action: "scale_up".into(),
            amount: 1,
            replicas: 3,
            group: Some("decode Gaudi3 tp1 pp1 b32".into()),
        });
        tl.events.push(TimelineEvent::Plan {
            t: 2.0,
            seq: 1,
            plan: b.clone(),
        });
        tl.events.push(TimelineEvent::Diff {
            t: 2.0,
            diff: crate::plan::PlanDiff::between(&a, &b),
        });
        tl.events.push(TimelineEvent::Rejection {
            t: 2.0,
            role: "decode".into(),
            group: Some("decode Gaudi3 tp1 pp1 b32".into()),
            reason: "planner re-plan moves decode classes mid-run".into(),
        });
        tl.events.push(TimelineEvent::Migration {
            t: 2.0,
            plan: lower_diff(&a, &b, 4e9).unwrap(),
            applied_s: Some(1.25),
        });
        tl.events.push(TimelineEvent::Summary {
            t: 10.0,
            requests: 32,
            output_tokens: 1024,
            makespan_s: 9.5,
        });
        tl
    }

    #[test]
    fn json_round_trip_is_identity() {
        let tl = sample();
        let text = tl.to_json_string();
        let back = Timeline::parse_json(&text).unwrap();
        assert_eq!(back, tl);
        // Byte-stable re-serialization (diffable artifacts).
        assert_eq!(back.to_json_string(), text);
    }

    #[test]
    fn counters_and_rollups() {
        let tl = sample();
        assert_eq!(tl.n_plans(), 2);
        assert_eq!(tl.n_migrations(), 1);
        assert_eq!(tl.n_decisions(), 1);
        assert_eq!(tl.n_rejections(), 1);
        assert_eq!(tl.plans().len(), 2);
        assert!((tl.sla_attainment() - 0.75).abs() < 1e-12);
        assert!(tl.summary().contains("1 migrations"));
    }

    #[test]
    fn rejection_group_round_trips_and_absent_parses_as_primary() {
        // Present: the group id survives the round trip.
        let tl = sample();
        let back = Timeline::parse_json(&tl.to_json_string()).unwrap();
        let rej = back
            .events
            .iter()
            .find(|e| matches!(e, TimelineEvent::Rejection { .. }))
            .unwrap();
        let TimelineEvent::Rejection { group, .. } = rej else {
            unreachable!()
        };
        assert_eq!(group.as_deref(), Some("decode Gaudi3 tp1 pp1 b32"));

        // Absent (a record written before diffs became group-granular):
        // parses as None — the role's primary group — and re-serializes
        // without inventing the field.
        let mut old = sample();
        for e in &mut old.events {
            match e {
                TimelineEvent::Rejection { group, .. }
                | TimelineEvent::Decision { group, .. } => *group = None,
                _ => {}
            }
        }
        let text = old.to_json_string();
        assert!(
            !text.contains("\"group\""),
            "pre-group records must not grow a group field"
        );
        let back = Timeline::parse_json(&text).unwrap();
        assert_eq!(back, old);
        assert_eq!(back.to_json_string(), text, "byte-stable");
    }

    #[test]
    fn window_attribution_round_trips_and_absent_stays_absent() {
        use crate::obs::critical_path::attribute_all;
        use crate::obs::trace::{Span, SpanKind};

        // Untraced record: no attribution field is ever written.
        let plain = sample();
        let text = plain.to_json_string();
        assert!(
            !text.contains("\"attribution\""),
            "untraced windows must not grow an attribution field"
        );

        // Traced record: the attribution survives the round trip.
        let spans = vec![
            Span {
                request: 1,
                node: -1,
                kind: SpanKind::Request,
                group: String::new(),
                chassis: 0,
                t_start: 0.0,
                t_end: 1.0,
                parent: -1,
                queue_wait: 0.1,
            },
            Span {
                request: 1,
                node: 0,
                kind: SpanKind::Host,
                group: "host".into(),
                chassis: 0,
                t_start: 0.1,
                t_end: 1.0,
                parent: -1,
                queue_wait: 0.0,
            },
        ];
        let mut tl = sample();
        for e in &mut tl.events {
            if let TimelineEvent::Window { attribution, .. } = e {
                *attribution = Some(attribute_all(&spans));
            }
        }
        let text = tl.to_json_string();
        assert!(text.contains("\"attribution\""));
        let back = Timeline::parse_json(&text).unwrap();
        assert_eq!(back, tl);
        assert_eq!(back.to_json_string(), text, "byte-stable");
    }

    #[test]
    fn cross_group_rebalances_counted_from_diffs() {
        let mut tl = sample();
        assert_eq!(tl.n_cross_group_rebalances(), 0, "primary-group resize only");
        let a = tiny_plan();
        let mut b = tiny_plan();
        b.bindings[2].token_fraction = 0.5;
        tl.events.push(TimelineEvent::Diff {
            t: 3.0,
            diff: crate::plan::PlanDiff::between(&a, &b),
        });
        assert_eq!(tl.n_cross_group_rebalances(), 1);
    }

    #[test]
    fn unapplied_migration_round_trips_as_null() {
        let mut tl = sample();
        if let Some(TimelineEvent::Migration { applied_s, .. }) = tl
            .events
            .iter_mut()
            .find(|e| matches!(e, TimelineEvent::Migration { .. }))
        {
            *applied_s = None;
        }
        let back = Timeline::parse_json(&tl.to_json_string()).unwrap();
        assert_eq!(back, tl);
    }

    #[test]
    fn garbage_rejected() {
        assert!(Timeline::parse_json("{}").is_err());
        assert!(Timeline::parse_json("not json").is_err());
        let mut tl = sample();
        tl.events.clear();
        let mut j = tl.to_json();
        j.try_set("events", vec![crate::jobj! { "kind" => "mystery" }])
            .unwrap();
        assert!(Timeline::from_json(&j).is_err());
    }
}
