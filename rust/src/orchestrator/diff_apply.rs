//! Lowering [`PlanDiff`]s into capacity-safe migrations, and the
//! retargeting / replay helpers the control loop and its property
//! tests share.
//!
//! Invariants this module guarantees (and `rust/tests/orchestrator_props.rs`
//! hammers):
//!
//! * a [`MigrationPlan`] replayed step-by-step over the source fleet
//!   never drives any (device, role) capacity negative — activations
//!   are ordered before the drains they replace;
//! * the final capacity map equals the target fleet exactly;
//! * [`retarget`] always yields a plan that passes
//!   [`ExecutionPlan::validate`], keeps ≥ 1 replica per role, and
//!   re-packs chassis consecutively.

use std::collections::BTreeMap;

use crate::plan::{ExecutionPlan, Role};
use crate::planner::migration::{
    plan_migration, role_replicas, MigrationPlan, MigrationStep, RoleMap,
};
use crate::{Error, Result};

/// Shape-granular capacity view: one key per pipeline *shape*
/// (device + TP×PP + batch limit — the same identity `plan/diff.rs`
/// and `DagSim::apply_fleet` match on), so a TP or batch-limit rebuild
/// surfaces as drain + activate steps instead of vanishing at plain
/// (device, role) granularity. The device label carries the shape so
/// migration steps stay self-describing.
pub fn shape_map_of(plan: &ExecutionPlan) -> RoleMap {
    let mut m = RoleMap::new();
    for p in &plan.pipelines {
        let device = format!("{} tp{} pp{} b{}", p.device, p.tp, p.pp, p.max_batch);
        *m.entry((device, p.role.name().to_string())).or_insert(0) += p.replicas;
    }
    m
}

/// Total (replicas × max_batch) slots a plan deploys for one role.
pub fn role_capacity(plan: &ExecutionPlan, role: Role) -> f64 {
    plan.pipelines
        .iter()
        .filter(|p| p.role == role)
        .map(|p| (p.replicas as u64 * p.max_batch) as f64)
        .sum()
}

/// Emit a new plan with the per-role replica totals moved to
/// `prefill_total` / `decode_total` (each clamped to ≥ 1).
///
/// The delta lands on the role's first (primary) pipeline group — the
/// one the configuration explorer shaped — and chassis are re-packed
/// consecutively. Admission rate follows decode capacity so the token
/// bucket tracks what the resized fleet can actually absorb.
pub fn retarget(plan: &ExecutionPlan, prefill_total: u32, decode_total: u32) -> ExecutionPlan {
    let mut out = plan.clone();
    for (role, want_total) in [
        (Role::Prefill, prefill_total.max(1)),
        (Role::Decode, decode_total.max(1)),
    ] {
        let have_total = role_replicas(plan, role);
        if have_total == 0 {
            continue; // role absent (e.g. CPU-only plan)
        }
        let delta = want_total as i64 - have_total as i64;
        if delta == 0 {
            continue;
        }
        if let Some(g) = out.pipelines.iter_mut().find(|p| p.role == role) {
            g.replicas = (g.replicas as i64 + delta).max(1) as u32;
        }
    }
    // Re-pack chassis consecutively in declaration order.
    let mut chassis = 0u32;
    for p in &mut out.pipelines {
        p.chassis = chassis;
        chassis += p.replicas;
    }
    // Admission tracks decode capacity.
    let old_cap = role_capacity(plan, Role::Decode);
    let new_cap = role_capacity(&out, Role::Decode);
    if old_cap > 0.0 && new_cap > 0.0 && (new_cap - old_cap).abs() > 0.0 {
        out.admission.rate = plan.admission.rate * new_cap / old_cap;
    }
    out
}

/// Lower the move `from → to` into an ordered, capacity-safe
/// [`MigrationPlan`], pricing the KV motion over `from`'s fabric.
///
/// Capacity is compared at *shape* granularity ([`shape_map_of`]), so
/// same-device rebuilds (TP/PP/batch changes) produce real drain +
/// activate + KV-transfer steps — matching what `DagSim::apply_fleet`
/// actually does to the fleet. `kv_resident_bytes` is the KV currently
/// parked on decode pipelines (the simulator reports it per window);
/// each drained decode pipeline is priced at its share.
pub fn lower_diff(
    from: &ExecutionPlan,
    to: &ExecutionPlan,
    kv_resident_bytes: f64,
) -> Result<MigrationPlan> {
    let cur = shape_map_of(from);
    let tgt = shape_map_of(to);
    let decode_pipes = role_replicas(from, Role::Decode).max(1);
    let kv_per_pipeline = (kv_resident_bytes / decode_pipes as f64).max(0.0);
    let fabric = from.build_fabric()?;
    Ok(plan_migration(&cur, &tgt, kv_per_pipeline, &fabric))
}

/// Replay a step list over `current`, returning the capacity map after
/// every step (index 0 = the starting map). Errs if any drain would
/// push a (device, role) capacity negative — the safety property every
/// migration must satisfy.
pub fn capacity_trajectory(
    current: &RoleMap,
    steps: &[MigrationStep],
) -> Result<Vec<RoleMap>> {
    let mut m = current.clone();
    let mut out = vec![m.clone()];
    for step in steps {
        match step {
            MigrationStep::Activate {
                device,
                role,
                count,
            } => {
                *m.entry((device.clone(), role.clone())).or_insert(0) += count;
            }
            MigrationStep::Drain {
                device,
                role,
                count,
            } => {
                let key = (device.clone(), role.clone());
                let have = m.get(&key).copied().unwrap_or(0);
                if have < *count {
                    return Err(Error::Capacity(format!(
                        "drain of {count}× {device}/{role} underflows capacity {have}"
                    )));
                }
                match have - count {
                    0 => {
                        m.remove(&key);
                    }
                    left => {
                        m.insert(key, left);
                    }
                }
            }
            MigrationStep::TransferKv { bytes, .. } => {
                if *bytes < 0.0 || !bytes.is_finite() {
                    return Err(Error::Capacity(format!(
                        "KV transfer of {bytes} bytes is nonsense"
                    )));
                }
            }
        }
        out.push(m.clone());
    }
    Ok(out)
}

/// Does replaying `steps` over `current` land exactly on `target`?
/// (Zero-count entries are normalized away on both sides.)
pub fn converges(current: &RoleMap, target: &RoleMap, steps: &[MigrationStep]) -> bool {
    let Ok(traj) = capacity_trajectory(current, steps) else {
        return false;
    };
    let norm = |m: &RoleMap| -> BTreeMap<(String, String), u32> {
        m.iter()
            .filter(|(_, &v)| v > 0)
            .map(|(k, &v)| (k.clone(), v))
            .collect()
    };
    norm(traj.last().unwrap()) == norm(target)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::tests::tiny_plan;

    #[test]
    fn retarget_scales_roles_and_repacks_chassis() {
        let plan = tiny_plan(); // 1× H100 prefill @0, 2× Gaudi3 decode @1
        let up = retarget(&plan, 1, 5);
        up.validate().unwrap();
        assert_eq!(role_replicas(&up, Role::Decode), 5);
        assert_eq!(role_replicas(&up, Role::Prefill), 1);
        assert_eq!(up.pipelines[0].chassis, 0);
        assert_eq!(up.pipelines[1].chassis, 1);
        assert_eq!(up.n_chassis(), 6);
        // Admission rate scaled with decode capacity (2×32 → 5×32).
        assert!((up.admission.rate - plan.admission.rate * 2.5).abs() < 1e-9);

        // Shrinking clamps at one replica per role.
        let down = retarget(&plan, 0, 0);
        down.validate().unwrap();
        assert_eq!(role_replicas(&down, Role::Prefill), 1);
        assert_eq!(role_replicas(&down, Role::Decode), 1);
    }

    #[test]
    fn lower_diff_produces_convergent_capacity_safe_steps() {
        let a = tiny_plan();
        let b = retarget(&a, 2, 4);
        let m = lower_diff(&a, &b, 8e9).unwrap();
        let cur = shape_map_of(&a);
        let tgt = shape_map_of(&b);
        // Replay is capacity-safe at every step...
        let traj = capacity_trajectory(&cur, &m.steps).unwrap();
        assert_eq!(traj.len(), m.steps.len() + 1);
        // ...and lands exactly on the target fleet.
        assert!(converges(&cur, &tgt, &m.steps));
        // Pure growth moves no KV.
        assert_eq!(m.kv_bytes, 0.0);
    }

    #[test]
    fn shrink_prices_kv_share_per_drained_pipeline() {
        let a = tiny_plan(); // 2 decode pipelines
        let b = retarget(&a, 1, 1); // drain one
        let m = lower_diff(&a, &b, 8e9).unwrap();
        // 8 GB resident over 2 pipelines → 4 GB leaves with the drained one.
        assert!((m.kv_bytes - 4e9).abs() < 1.0, "kv={}", m.kv_bytes);
        assert!(m.est_duration_s > 1.0);
        assert!(converges(&shape_map_of(&a), &shape_map_of(&b), &m.steps));
    }

    #[test]
    fn shape_rebuild_is_a_real_migration() {
        // Same device, same replica count, different TP: invisible at
        // (device, role) granularity but a full rebuild in the fleet —
        // the migration must drain the old shape, move its KV, and
        // activate the new one.
        let a = tiny_plan();
        let mut b = tiny_plan();
        b.pipelines[1].tp = 2; // decode Gaudi3 rebuilt at TP2
        let m = lower_diff(&a, &b, 8e9).unwrap();
        assert!(
            m.steps
                .iter()
                .any(|s| matches!(s, MigrationStep::Activate { .. })),
            "rebuild must activate the new shape: {:?}",
            m.steps
        );
        assert!(
            m.steps
                .iter()
                .any(|s| matches!(s, MigrationStep::Drain { .. })),
            "rebuild must drain the old shape"
        );
        assert!(m.kv_bytes > 0.0, "decode rebuild moves resident KV");
        assert!(converges(&shape_map_of(&a), &shape_map_of(&b), &m.steps));
    }

    #[test]
    fn trajectory_rejects_underflow() {
        let cur = RoleMap::new();
        let steps = vec![MigrationStep::Drain {
            device: "H100".into(),
            role: "decode".into(),
            count: 1,
        }];
        assert!(capacity_trajectory(&cur, &steps).is_err());
        assert!(!converges(&cur, &cur, &steps));
    }
}
