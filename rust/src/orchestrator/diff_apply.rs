//! Lowering [`PlanDiff`]s into capacity-safe migrations, and the
//! retargeting / replay helpers the control loop and its property
//! tests share.
//!
//! Invariants this module guarantees (and `rust/tests/orchestrator_props.rs`
//! hammers):
//!
//! * a [`MigrationPlan`] replayed step-by-step over the source fleet
//!   never drives any (device, role) capacity negative — activations
//!   are ordered before the drains they replace;
//! * the final capacity map equals the target fleet exactly;
//! * [`retarget`] always yields a plan that passes
//!   [`ExecutionPlan::validate`], keeps ≥ 1 replica per *group* (every
//!   bound class stays servable), and re-packs chassis consecutively.
//!
//! Retargeting is **heterogeneity-aware**: a role's replica delta is
//! distributed across its pipeline groups by the cost model's
//! [`score_groups`] ranking — growth lands on the cheapest
//! $/throughput group, shrinkage retires the worst-TCO capacity first
//! — and [`retune_token_fractions`] re-aligns expert-style sibling
//! bindings' token splits with the resulting per-class capacity, so a
//! replica shift between hardware generations also shifts the load.

use std::collections::BTreeMap;

use crate::plan::{ExecutionPlan, Role, Stage};
use crate::planner::autoscale::{cheapest, rank, score_groups};
use crate::planner::migration::{
    plan_migration_routed, role_replicas, KvRoute, MigrationPlan, MigrationStep, RoleMap,
};
use crate::{Error, Result};

/// Shape-granular capacity view: one key per pipeline *shape*
/// (device + TP×PP + batch limit — the same identity `plan/diff.rs`
/// and `DagSim::apply_fleet` match on), so a TP or batch-limit rebuild
/// surfaces as drain + activate steps instead of vanishing at plain
/// (device, role) granularity. The device label carries the shape so
/// migration steps stay self-describing.
pub fn shape_map_of(plan: &ExecutionPlan) -> RoleMap {
    let mut m = RoleMap::new();
    for p in &plan.pipelines {
        let device = format!("{} tp{} pp{} b{}", p.device, p.tp, p.pp, p.max_batch);
        *m.entry((device, p.role.name().to_string())).or_insert(0) += p.replicas;
    }
    m
}

/// Total (replicas × max_batch) slots a plan deploys for one role.
pub fn role_capacity(plan: &ExecutionPlan, role: Role) -> f64 {
    plan.pipelines
        .iter()
        .filter(|p| p.role == role)
        .map(|p| (p.replicas as u64 * p.max_batch) as f64)
        .sum()
}

/// Re-pack chassis consecutively and track admission to the new decode
/// capacity — the finishing step every retarget/rebalance shares.
fn finalize_fleet(from: &ExecutionPlan, out: &mut ExecutionPlan) {
    let mut chassis = 0u32;
    for p in &mut out.pipelines {
        p.chassis = chassis;
        chassis += p.replicas;
    }
    let old_cap = role_capacity(from, Role::Decode);
    let new_cap = role_capacity(out, Role::Decode);
    if old_cap > 0.0 && new_cap > 0.0 && (new_cap - old_cap).abs() > 0.0 {
        out.admission.rate = from.admission.rate * new_cap / old_cap;
    }
}

/// Indices of a role's pipeline groups, in declaration order.
fn groups_of(plan: &ExecutionPlan, role: Role) -> Vec<usize> {
    plan.pipelines
        .iter()
        .enumerate()
        .filter(|(_, p)| p.role == role)
        .map(|(g, _)| g)
        .collect()
}

/// Distribute a role's replica total across its groups by the cost
/// model's ranking: growth goes to the **cheapest** $/throughput group,
/// shrinkage retires the **worst-TCO** groups first, flooring every
/// group at one replica so no bound class is ever stranded. Ties break
/// on declaration order (deterministic).
fn distribute_role(out: &mut ExecutionPlan, role: Role, want_total: u32) {
    let idxs = groups_of(out, role);
    if idxs.is_empty() {
        return; // role absent (e.g. CPU-only plan)
    }
    let have: u32 = idxs.iter().map(|&g| out.pipelines[g].replicas).sum();
    // Floor: one replica per group keeps every class servable.
    let want = want_total.max(idxs.len() as u32);
    if want == have {
        return;
    }
    let scores = score_groups(out, role);
    if want > have {
        // Buy the cheapest capacity that serves this role.
        let best = cheapest(&scores).map(|s| s.group).unwrap_or(idxs[0]);
        out.pipelines[best].replicas += want - have;
    } else {
        // Retire the worst-TCO capacity first.
        let mut order: Vec<_> = scores.iter().collect();
        order.sort_by(|a, b| rank(b, a));
        let mut need = have - want;
        for s in order {
            if need == 0 {
                break;
            }
            let take = need.min(out.pipelines[s.group].replicas.saturating_sub(1));
            out.pipelines[s.group].replicas -= take;
            need -= take;
        }
    }
}

/// Emit a new plan with the per-role replica totals moved to
/// `prefill_total` / `decode_total` (each clamped to ≥ 1 per group).
///
/// The delta is distributed across the role's pipeline groups by the
/// planner's cost model (see [`distribute_role`]) — on a heterogeneous
/// fleet, scale-ups buy the cheapest capacity and scale-downs retire
/// the worst-TCO generation first; on a single-group fleet this is the
/// classic primary-group resize. Chassis are re-packed consecutively
/// and the admission rate follows decode capacity so the token bucket
/// tracks what the resized fleet can actually absorb.
pub fn retarget(plan: &ExecutionPlan, prefill_total: u32, decode_total: u32) -> ExecutionPlan {
    let mut out = plan.clone();
    distribute_role(&mut out, Role::Prefill, prefill_total.max(1));
    distribute_role(&mut out, Role::Decode, decode_total.max(1));
    finalize_fleet(plan, &mut out);
    out
}

/// Pure cross-group rebalance: move `n` replicas of `role` from the
/// group keyed `from_key` to the group keyed `to_key` (shape keys, see
/// [`crate::plan::PipelineBinding::shape_key`]), leaving the role total
/// unchanged. The source keeps ≥ 1 replica; unknown keys are a no-op.
pub fn rebalance(
    plan: &ExecutionPlan,
    role: Role,
    from_key: &str,
    to_key: &str,
    n: u32,
) -> ExecutionPlan {
    let mut out = plan.clone();
    let find = |p: &ExecutionPlan, key: &str| -> Option<usize> {
        p.pipelines
            .iter()
            .enumerate()
            .find(|(_, g)| g.role == role && g.shape_key() == key)
            .map(|(g, _)| g)
    };
    let (Some(src), Some(dst)) = (find(&out, from_key), find(&out, to_key)) else {
        return out;
    };
    if src == dst {
        return out;
    }
    let moved = n.min(out.pipelines[src].replicas.saturating_sub(1));
    if moved == 0 {
        return out;
    }
    out.pipelines[src].replicas -= moved;
    out.pipelines[dst].replicas += moved;
    finalize_fleet(plan, &mut out);
    out
}

/// Re-align expert-style sibling bindings' token fractions with the
/// deployed per-class capacity share. Siblings are LLM bindings of the
/// same stage with identical dependency lists and ≥ 2 distinct classes
/// — the split the mixed-generation plans route load through. The
/// sibling set's total fraction is preserved **exactly** (shares sum
/// to 1, no per-member floor that could push the partition above its
/// total at extreme capacity ratios; each member capped at 1.0 for
/// plan validity), so a replica shift between generations moves the
/// *work*, not just the hardware. Sets with a zero-capacity member are
/// left untouched — a fraction of 0 would not validate.
pub fn retune_token_fractions(plan: &ExecutionPlan) -> ExecutionPlan {
    let mut out = plan.clone();
    let mut sets: BTreeMap<(&'static str, Vec<usize>), Vec<usize>> = BTreeMap::new();
    for (i, b) in plan.bindings.iter().enumerate() {
        let role = match b.stage {
            Stage::LlmPrefill => Role::Prefill,
            Stage::LlmDecode => Role::Decode,
            Stage::Cpu => continue,
        };
        sets.entry((role.name(), b.deps.clone())).or_default().push(i);
    }
    for ((role_name, _), members) in sets {
        if members.len() < 2 {
            continue;
        }
        let distinct: std::collections::BTreeSet<&str> = members
            .iter()
            .map(|&i| plan.bindings[i].class.as_str())
            .collect();
        if distinct.len() < 2 {
            continue;
        }
        let role = if role_name == Role::Prefill.name() {
            Role::Prefill
        } else {
            Role::Decode
        };
        let class_capacity = |class: &str| -> f64 {
            plan.pipelines
                .iter()
                .filter(|p| p.role == role && p.device == class)
                .map(|p| (p.replicas as u64 * p.max_batch) as f64)
                .sum()
        };
        // Members sharing a class split that class's capacity between
        // them, so per-member weights never double-count a class.
        let mut members_on: BTreeMap<&str, f64> = BTreeMap::new();
        for &i in &members {
            *members_on.entry(plan.bindings[i].class.as_str()).or_insert(0.0) += 1.0;
        }
        let weight = |i: usize| -> f64 {
            let class = plan.bindings[i].class.as_str();
            class_capacity(class) / members_on[class]
        };
        let total_fraction: f64 = members
            .iter()
            .map(|&i| plan.bindings[i].token_fraction)
            .sum();
        let total_weight: f64 = members.iter().map(|&i| weight(i)).sum();
        if total_weight <= 0.0 || members.iter().any(|&i| weight(i) <= 0.0) {
            continue;
        }
        for &i in &members {
            let share = weight(i) / total_weight;
            out.bindings[i].token_fraction = (total_fraction * share).min(1.0);
        }
    }
    out
}

/// Lower the move `from → to` into an ordered, capacity-safe
/// [`MigrationPlan`], pricing the KV motion on the contended transfer
/// clock over `from`'s fabric.
///
/// Capacity is compared at *shape* granularity ([`shape_map_of`]), so
/// same-device rebuilds (TP/PP/batch changes) produce real drain +
/// activate + KV-transfer steps — matching what `DagSim::apply_fleet`
/// actually does to the fleet. `kv_resident_bytes` is the KV currently
/// parked on decode pipelines (the simulator reports it per window);
/// each drained decode pipeline is priced at its share. Every drained
/// decode shape gets a real [`KvRoute`]: its own chassis to the chassis
/// of the cheapest surviving decode group in the target — the
/// cross-group move the heterogeneous retarget produces.
pub fn lower_diff(
    from: &ExecutionPlan,
    to: &ExecutionPlan,
    kv_resident_bytes: f64,
) -> Result<MigrationPlan> {
    let cur = shape_map_of(from);
    let tgt = shape_map_of(to);
    let decode_pipes = role_replicas(from, Role::Decode).max(1);
    let kv_per_pipeline = (kv_resident_bytes / decode_pipes as f64).max(0.0);
    let fabric = from.build_fabric()?;

    // Cheapest surviving decode capacity in the target absorbs the
    // drained sessions (the same ranking that placed the growth). The
    // migration runs on the *current* fleet layout, so the absorber's
    // chassis is resolved in `from` when its shape already exists there
    // (the target's re-packed numbering only applies after the move).
    let target_scores = score_groups(to, Role::Decode);
    let absorber = cheapest(&target_scores).map(|s| {
        let chassis = from
            .pipelines
            .iter()
            .find(|p| p.role == Role::Decode && p.shape_key() == s.key)
            .map(|p| p.chassis)
            .unwrap_or(to.pipelines[s.group].chassis);
        (chassis, s.key.clone())
    });
    let mut routes: BTreeMap<String, KvRoute> = BTreeMap::new();
    if let Some((to_chassis, to_label)) = absorber {
        for p in &from.pipelines {
            if p.role != Role::Decode {
                continue;
            }
            let shape = format!("{} tp{} pp{} b{}", p.device, p.tp, p.pp, p.max_batch);
            let key = (shape.clone(), Role::Decode.name().to_string());
            let have = cur.get(&key).copied().unwrap_or(0);
            let want = tgt.get(&key).copied().unwrap_or(0);
            if have > want {
                // Drains retire a group's top replicas first, so the KV
                // leaves from the group's highest chassis — distinct
                // from the absorber's base chassis even on intra-group
                // shrinks (survivors occupy the base).
                routes.entry(shape).or_insert(KvRoute {
                    from_chassis: p.chassis + p.replicas.saturating_sub(1),
                    to_chassis,
                    to_label: to_label.clone(),
                });
            }
        }
    }
    Ok(plan_migration_routed(
        &cur,
        &tgt,
        kv_per_pipeline,
        &fabric,
        &routes,
    ))
}

/// Replay a step list over `current`, returning the capacity map after
/// every step (index 0 = the starting map). Errs if any drain would
/// push a (device, role) capacity negative — the safety property every
/// migration must satisfy.
pub fn capacity_trajectory(
    current: &RoleMap,
    steps: &[MigrationStep],
) -> Result<Vec<RoleMap>> {
    let mut m = current.clone();
    let mut out = vec![m.clone()];
    for step in steps {
        match step {
            MigrationStep::Activate {
                device,
                role,
                count,
            } => {
                *m.entry((device.clone(), role.clone())).or_insert(0) += count;
            }
            MigrationStep::Drain {
                device,
                role,
                count,
            } => {
                let key = (device.clone(), role.clone());
                let have = m.get(&key).copied().unwrap_or(0);
                if have < *count {
                    return Err(Error::Capacity(format!(
                        "drain of {count}× {device}/{role} underflows capacity {have}"
                    )));
                }
                match have - count {
                    0 => {
                        m.remove(&key);
                    }
                    left => {
                        m.insert(key, left);
                    }
                }
            }
            MigrationStep::TransferKv { bytes, .. } => {
                if *bytes < 0.0 || !bytes.is_finite() {
                    return Err(Error::Capacity(format!(
                        "KV transfer of {bytes} bytes is nonsense"
                    )));
                }
            }
        }
        out.push(m.clone());
    }
    Ok(out)
}

/// Does replaying `steps` over `current` land exactly on `target`?
/// (Zero-count entries are normalized away on both sides.)
pub fn converges(current: &RoleMap, target: &RoleMap, steps: &[MigrationStep]) -> bool {
    let Ok(traj) = capacity_trajectory(current, steps) else {
        return false;
    };
    let norm = |m: &RoleMap| -> BTreeMap<(String, String), u32> {
        m.iter()
            .filter(|(_, &v)| v > 0)
            .map(|(k, &v)| (k.clone(), v))
            .collect()
    };
    norm(traj.last().unwrap()) == norm(target)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::tests::tiny_plan;

    #[test]
    fn retarget_scales_roles_and_repacks_chassis() {
        let plan = tiny_plan(); // 1× H100 prefill @0, 2× Gaudi3 decode @1
        let up = retarget(&plan, 1, 5);
        up.validate().unwrap();
        assert_eq!(role_replicas(&up, Role::Decode), 5);
        assert_eq!(role_replicas(&up, Role::Prefill), 1);
        assert_eq!(up.pipelines[0].chassis, 0);
        assert_eq!(up.pipelines[1].chassis, 1);
        assert_eq!(up.n_chassis(), 6);
        // Admission rate scaled with decode capacity (2×32 → 5×32).
        assert!((up.admission.rate - plan.admission.rate * 2.5).abs() < 1e-9);

        // Shrinking clamps at one replica per role.
        let down = retarget(&plan, 0, 0);
        down.validate().unwrap();
        assert_eq!(role_replicas(&down, Role::Prefill), 1);
        assert_eq!(role_replicas(&down, Role::Decode), 1);
    }

    #[test]
    fn lower_diff_produces_convergent_capacity_safe_steps() {
        let a = tiny_plan();
        let b = retarget(&a, 2, 4);
        let m = lower_diff(&a, &b, 8e9).unwrap();
        let cur = shape_map_of(&a);
        let tgt = shape_map_of(&b);
        // Replay is capacity-safe at every step...
        let traj = capacity_trajectory(&cur, &m.steps).unwrap();
        assert_eq!(traj.len(), m.steps.len() + 1);
        // ...and lands exactly on the target fleet.
        assert!(converges(&cur, &tgt, &m.steps));
        // Pure growth moves no KV.
        assert_eq!(m.kv_bytes, 0.0);
    }

    #[test]
    fn shrink_prices_kv_share_per_drained_pipeline() {
        let a = tiny_plan(); // 2 decode pipelines
        let b = retarget(&a, 1, 1); // drain one
        let m = lower_diff(&a, &b, 8e9).unwrap();
        // 8 GB resident over 2 pipelines → 4 GB leaves with the drained one.
        assert!((m.kv_bytes - 4e9).abs() < 1.0, "kv={}", m.kv_bytes);
        assert!(m.est_duration_s > 1.0);
        assert!(converges(&shape_map_of(&a), &shape_map_of(&b), &m.steps));
    }

    #[test]
    fn shape_rebuild_is_a_real_migration() {
        // Same device, same replica count, different TP: invisible at
        // (device, role) granularity but a full rebuild in the fleet —
        // the migration must drain the old shape, move its KV, and
        // activate the new one.
        let a = tiny_plan();
        let mut b = tiny_plan();
        b.pipelines[1].tp = 2; // decode Gaudi3 rebuilt at TP2
        let m = lower_diff(&a, &b, 8e9).unwrap();
        assert!(
            m.steps
                .iter()
                .any(|s| matches!(s, MigrationStep::Activate { .. })),
            "rebuild must activate the new shape: {:?}",
            m.steps
        );
        assert!(
            m.steps
                .iter()
                .any(|s| matches!(s, MigrationStep::Drain { .. })),
            "rebuild must drain the old shape"
        );
        assert!(m.kv_bytes > 0.0, "decode rebuild moves resident KV");
        assert!(converges(&shape_map_of(&a), &shape_map_of(&b), &m.steps));
    }

    #[test]
    fn retarget_distributes_delta_by_tco_score() {
        use crate::plan::presets::mixed_generation;
        use crate::planner::autoscale::score_groups;

        let plan = mixed_generation("8b-fp16", "H100", "A100", 2, 2);
        let scores = score_groups(&plan, Role::Decode);
        let cheapest = scores
            .iter()
            .min_by(|a, b| a.score.partial_cmp(&b.score).unwrap())
            .unwrap()
            .group;
        let worst = scores
            .iter()
            .max_by(|a, b| a.score.partial_cmp(&b.score).unwrap())
            .unwrap()
            .group;
        assert_ne!(cheapest, worst, "two generations must rank differently");

        // Scale-up buys only the cheapest group's capacity.
        let up = retarget(&plan, 1, 7);
        up.validate().unwrap();
        assert_eq!(role_replicas(&up, Role::Decode), 7);
        assert_eq!(
            up.pipelines[cheapest].replicas,
            plan.pipelines[cheapest].replicas + 3,
            "growth lands on the cheapest $/throughput group"
        );
        assert_eq!(up.pipelines[worst].replicas, plan.pipelines[worst].replicas);

        // Scale-down retires the worst-TCO capacity first (floor 1).
        let down = retarget(&plan, 1, 2);
        down.validate().unwrap();
        assert_eq!(role_replicas(&down, Role::Decode), 2);
        assert_eq!(
            down.pipelines[worst].replicas, 1,
            "the expensive generation drains to its floor first"
        );
        assert_eq!(down.pipelines[cheapest].replicas, 1);
        // The floor holds: a role never drops below one replica/group.
        let floor = retarget(&plan, 0, 0);
        floor.validate().unwrap();
        assert_eq!(role_replicas(&floor, Role::Decode), 2);
    }

    #[test]
    fn rebalance_moves_replicas_between_groups_without_changing_total() {
        use crate::plan::presets::mixed_generation;

        let plan = mixed_generation("8b-fp16", "H100", "A100", 1, 3);
        let from_key = plan.pipelines[2].shape_key(); // decode A100
        let to_key = plan.pipelines[1].shape_key(); // decode H100
        let out = rebalance(&plan, Role::Decode, &from_key, &to_key, 2);
        out.validate().unwrap();
        assert_eq!(role_replicas(&out, Role::Decode), 4, "total unchanged");
        assert_eq!(out.pipelines[1].replicas, 3);
        assert_eq!(out.pipelines[2].replicas, 1);
        // The diff is a genuine cross-group rebalance.
        let d = crate::plan::PlanDiff::between(&plan, &out);
        assert!(d.is_cross_group(), "{}", d.summary());
        // Source floor: never drains a group to zero.
        let all = rebalance(&plan, Role::Decode, &from_key, &to_key, 99);
        assert_eq!(all.pipelines[2].replicas, 1);
        // Unknown keys are a no-op.
        let noop = rebalance(&plan, Role::Decode, "nope", &to_key, 1);
        assert_eq!(noop, plan);
    }

    #[test]
    fn retune_follows_capacity_share() {
        use crate::plan::presets::mixed_generation;

        // Equal capacity → 0.5/0.5 split (the preset's starting point).
        let plan = mixed_generation("8b-fp16", "H100", "A100", 2, 2);
        let same = retune_token_fractions(&plan);
        assert_eq!(same, plan, "unchanged capacity must be a fixed point");

        // Shift capacity 3:1 → fractions follow 0.75/0.25.
        let from_key = plan.pipelines[2].shape_key();
        let to_key = plan.pipelines[1].shape_key();
        let shifted = rebalance(&plan, Role::Decode, &from_key, &to_key, 1);
        let retuned = retune_token_fractions(&shifted);
        assert!((retuned.bindings[2].token_fraction - 0.75).abs() < 1e-9);
        assert!((retuned.bindings[3].token_fraction - 0.25).abs() < 1e-9);
        retuned.validate().unwrap();
        let d = crate::plan::PlanDiff::between(&shifted, &retuned);
        assert_eq!(d.retuned.len(), 2, "both siblings retype: {}", d.summary());

        // Single-class plans are untouched.
        let tiny = tiny_plan();
        assert_eq!(retune_token_fractions(&tiny), tiny);
    }

    #[test]
    fn cross_group_shift_lowers_to_a_routed_capacity_safe_migration() {
        use crate::plan::presets::mixed_generation;

        let plan = mixed_generation("8b-fp16", "H100", "A100", 1, 3);
        let from_key = plan.pipelines[2].shape_key();
        let to_key = plan.pipelines[1].shape_key();
        let target = rebalance(&plan, Role::Decode, &from_key, &to_key, 2);
        let m = lower_diff(&plan, &target, 8e9).unwrap();
        // Capacity-safe and convergent at shape granularity.
        let cur = shape_map_of(&plan);
        let tgt = shape_map_of(&target);
        capacity_trajectory(&cur, &m.steps).unwrap();
        assert!(converges(&cur, &tgt, &m.steps));
        // The drained generation's KV is routed to a *named* surviving
        // group, not the anonymous fleet.
        assert!(
            m.steps.iter().any(|s| matches!(
                s,
                MigrationStep::TransferKv { to, .. } if to.starts_with("decode ")
            )),
            "KV route must name the absorbing group: {:?}",
            m.steps
        );
        // 8 GB over 4 decode pipes → 2 GB leaves with each of the 2
        // drained A100 pipelines.
        assert!((m.kv_bytes - 4e9).abs() < 1.0, "kv={}", m.kv_bytes);
        assert!(m.est_duration_s > 1.0, "real cross-chassis hop priced");
    }

    #[test]
    fn trajectory_rejects_underflow() {
        let cur = RoleMap::new();
        let steps = vec![MigrationStep::Drain {
            device: "H100".into(),
            role: "decode".into(),
            count: 1,
        }];
        assert!(capacity_trajectory(&cur, &steps).is_err());
        assert!(!converges(&cur, &cur, &steps));
    }
}
