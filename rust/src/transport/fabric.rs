//! Fabric topology: chassis with scale-up domains, RoCE scale-out links,
//! and per-link FIFO contention.

use crate::{Error, Result};

/// Address of an accelerator: (chassis, slot).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeAddr {
    pub chassis: u32,
    pub slot: u32,
}

impl NodeAddr {
    pub fn same_chassis(&self, other: &NodeAddr) -> bool {
        self.chassis == other.chassis
    }
}

/// Identifier of a directional link in the fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkId {
    /// Intra-chassis (scale-up) link of a chassis.
    ScaleUp(u32),
    /// NIC of a chassis onto the RoCE network (egress/ingress modeled
    /// as one full-duplex pipe per direction pair).
    ScaleOut(u32),
}

/// One contended pipe: serialized FIFO reservation model. A transfer of
/// `bytes` starting at `now` completes at
/// `max(now, busy_until) + latency + bytes / bandwidth`.
#[derive(Debug, Clone)]
pub struct Link {
    pub bw_bytes_per_s: f64,
    pub latency_s: f64,
    pub busy_until_s: f64,
    /// Total bytes carried (utilization accounting).
    pub bytes_carried: f64,
}

impl Link {
    pub fn new(bw_gbit: f64, latency_s: f64) -> Link {
        Link {
            bw_bytes_per_s: bw_gbit * 1e9 / 8.0,
            latency_s,
            busy_until_s: 0.0,
            bytes_carried: 0.0,
        }
    }

    /// Reserve the link for a transfer; returns (start, completion).
    pub fn reserve(&mut self, bytes: f64, now_s: f64) -> (f64, f64) {
        let start = now_s.max(self.busy_until_s);
        let done = start + self.latency_s + bytes / self.bw_bytes_per_s;
        self.busy_until_s = done;
        self.bytes_carried += bytes;
        (start, done)
    }

    /// Completion time without reserving (what-if query).
    pub fn peek(&self, bytes: f64, now_s: f64) -> f64 {
        now_s.max(self.busy_until_s) + self.latency_s + bytes / self.bw_bytes_per_s
    }
}

/// The cluster fabric: per-chassis scale-up pipes + per-chassis NICs.
#[derive(Debug, Clone)]
pub struct Fabric {
    pub n_chassis: u32,
    pub slots_per_chassis: u32,
    scaleup: Vec<Link>,
    scaleout: Vec<Link>,
}

/// Default RoCE latencies (§5.2's "modern AI datacenter" assumptions).
pub const SCALEUP_LATENCY_S: f64 = 2e-6;
pub const SCALEOUT_LATENCY_S: f64 = 10e-6;

impl Fabric {
    /// Build a fabric of `n_chassis` × `slots` with the given bandwidths
    /// (scale-up in GB/s per the device spec; scale-out in Gbit/s).
    pub fn new(
        n_chassis: u32,
        slots_per_chassis: u32,
        scaleup_gbps: f64,
        scaleout_gbit: f64,
    ) -> Fabric {
        Fabric {
            n_chassis,
            slots_per_chassis,
            scaleup: (0..n_chassis)
                .map(|_| Link::new(scaleup_gbps * 8.0, SCALEUP_LATENCY_S))
                .collect(),
            scaleout: (0..n_chassis)
                .map(|_| Link::new(scaleout_gbit, SCALEOUT_LATENCY_S))
                .collect(),
        }
    }

    pub fn validate_addr(&self, a: NodeAddr) -> Result<()> {
        if a.chassis >= self.n_chassis || a.slot >= self.slots_per_chassis {
            return Err(Error::Runtime(format!(
                "address {a:?} outside fabric ({}x{})",
                self.n_chassis, self.slots_per_chassis
            )));
        }
        Ok(())
    }

    /// Schedule a transfer between accelerators; returns completion time.
    ///
    /// Same chassis ⇒ one scale-up hop. Cross chassis ⇒ source NIC +
    /// destination NIC (both contended) — the RoCE path.
    pub fn transfer(
        &mut self,
        from: NodeAddr,
        to: NodeAddr,
        bytes: f64,
        now_s: f64,
    ) -> Result<f64> {
        self.validate_addr(from)?;
        self.validate_addr(to)?;
        if from == to {
            return Ok(now_s); // local, free
        }
        if from.same_chassis(&to) {
            let (_, done) = self.scaleup[from.chassis as usize].reserve(bytes, now_s);
            Ok(done)
        } else {
            let (_, sent) = self.scaleout[from.chassis as usize].reserve(bytes, now_s);
            let (_, done) = self.scaleout[to.chassis as usize].reserve(bytes, sent);
            Ok(done)
        }
    }

    /// Non-reserving estimate of a transfer's completion.
    pub fn estimate(&self, from: NodeAddr, to: NodeAddr, bytes: f64, now_s: f64) -> f64 {
        if from == to {
            return now_s;
        }
        if from.same_chassis(&to) {
            self.scaleup[from.chassis as usize].peek(bytes, now_s)
        } else {
            let sent = self.scaleout[from.chassis as usize].peek(bytes, now_s);
            self.scaleout[to.chassis as usize].peek(bytes, sent)
        }
    }

    /// Grow the fabric to at least `n_chassis` chassis (same link
    /// bandwidths as the existing tiers). Orchestrated fleets activate
    /// pipelines on fresh chassis mid-run; shrinking never removes
    /// chassis — drained links simply go idle.
    pub fn grow(&mut self, n_chassis: u32) {
        while self.n_chassis < n_chassis {
            let up = match self.scaleup.first() {
                Some(l) => Link {
                    busy_until_s: 0.0,
                    bytes_carried: 0.0,
                    ..l.clone()
                },
                None => Link::new(900.0 * 8.0, SCALEUP_LATENCY_S),
            };
            let out = match self.scaleout.first() {
                Some(l) => Link {
                    busy_until_s: 0.0,
                    bytes_carried: 0.0,
                    ..l.clone()
                },
                None => Link::new(400.0, SCALEOUT_LATENCY_S),
            };
            self.scaleup.push(up);
            self.scaleout.push(out);
            self.n_chassis += 1;
        }
    }

    /// Clear reservation state (busy-until times and byte counters) so
    /// one fabric description can be replayed across simulation runs.
    pub fn reset(&mut self) {
        for l in self.scaleup.iter_mut().chain(self.scaleout.iter_mut()) {
            l.busy_until_s = 0.0;
            l.bytes_carried = 0.0;
        }
    }

    /// Total bytes carried per tier (utilization reporting).
    pub fn carried(&self) -> (f64, f64) {
        (
            self.scaleup.iter().map(|l| l.bytes_carried).sum(),
            self.scaleout.iter().map(|l| l.bytes_carried).sum(),
        )
    }
}

/// Chassis-granular contended transfer clock — the **shared** edge
/// timing model of the two execution backends. The DAG simulator
/// (`cluster/dag.rs`) drives it in modeled seconds; the live server's
/// dispatcher (`server/dag_exec.rs`) drives it in scaled wall-clock
/// converted to modeled seconds — so a cross-chassis payload pays the
/// same FIFO link reservation (bandwidth + latency + queueing behind
/// earlier transfers) no matter which backend executes the plan. Slot
/// addressing is deliberately dropped: plans place pipelines per
/// chassis, and both backends model hops NIC-to-NIC.
#[derive(Debug, Clone)]
pub struct TransferClock {
    fabric: Fabric,
}

impl TransferClock {
    pub fn new(fabric: Fabric) -> TransferClock {
        TransferClock { fabric }
    }

    /// Reserve the hop between two chassis; returns the completion time
    /// in the caller's (modeled) clock. Same chassis ⇒ free.
    pub fn transfer(
        &mut self,
        from_chassis: u32,
        to_chassis: u32,
        bytes: f64,
        now_s: f64,
    ) -> Result<f64> {
        self.fabric.transfer(
            NodeAddr {
                chassis: from_chassis,
                slot: 0,
            },
            NodeAddr {
                chassis: to_chassis,
                slot: 0,
            },
            bytes,
            now_s,
        )
    }

    /// Non-reserving estimate of the same hop.
    pub fn estimate(&self, from_chassis: u32, to_chassis: u32, bytes: f64, now_s: f64) -> f64 {
        self.fabric.estimate(
            NodeAddr {
                chassis: from_chassis,
                slot: 0,
            },
            NodeAddr {
                chassis: to_chassis,
                slot: 0,
            },
            bytes,
            now_s,
        )
    }

    /// Grow the underlying fabric (fleet changes activate pipelines on
    /// fresh chassis mid-run).
    pub fn grow(&mut self, n_chassis: u32) {
        self.fabric.grow(n_chassis);
    }

    /// Forget reservations so one clock description replays across runs.
    pub fn reset(&mut self) {
        self.fabric.reset();
    }

    /// Total bytes carried per tier (scale-up, scale-out).
    pub fn carried(&self) -> (f64, f64) {
        self.fabric.carried()
    }

    pub fn n_chassis(&self) -> u32 {
        self.fabric.n_chassis
    }
}

/// Thread-safe handle to one shared [`TransferClock`].
///
/// The live server runs each engine of the pool on its own worker
/// thread, but a cross-chassis prefill→decode KV handoff must still be
/// charged against the *same* chassis-granular FIFO reservation state
/// regardless of which thread finished the prefill. A single `Mutex`
/// (not sharded) is deliberate: the FIFO semantics of `Link::reserve`
/// are only well-defined when reservations on one link are totally
/// ordered, and the critical section is a handful of float ops — far
/// cheaper than the engine work on either side of it. Lock poisoning is
/// ignored (`into_inner`): the clock holds plain floats, so a panic in
/// an unrelated part of a holder's call stack cannot leave it torn.
#[derive(Debug, Clone)]
pub struct SharedTransferClock {
    inner: std::sync::Arc<std::sync::Mutex<TransferClock>>,
}

impl SharedTransferClock {
    pub fn new(fabric: Fabric) -> SharedTransferClock {
        SharedTransferClock {
            inner: std::sync::Arc::new(std::sync::Mutex::new(TransferClock::new(fabric))),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, TransferClock> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Reserve the hop between two chassis (see
    /// [`TransferClock::transfer`]). Takes `&self`: reservation order
    /// across threads is whatever order callers win the lock — exactly
    /// the FIFO arrival order the link model wants.
    pub fn transfer(
        &self,
        from_chassis: u32,
        to_chassis: u32,
        bytes: f64,
        now_s: f64,
    ) -> Result<f64> {
        self.lock().transfer(from_chassis, to_chassis, bytes, now_s)
    }

    /// Non-reserving estimate of the same hop.
    pub fn estimate(&self, from_chassis: u32, to_chassis: u32, bytes: f64, now_s: f64) -> f64 {
        self.lock().estimate(from_chassis, to_chassis, bytes, now_s)
    }

    /// Grow the underlying fabric.
    pub fn grow(&self, n_chassis: u32) {
        self.lock().grow(n_chassis);
    }

    /// Forget reservations.
    pub fn reset(&self) {
        self.lock().reset();
    }

    /// Total bytes carried per tier (scale-up, scale-out).
    pub fn carried(&self) -> (f64, f64) {
        self.lock().carried()
    }

    pub fn n_chassis(&self) -> u32 {
        self.lock().n_chassis()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric() -> Fabric {
        // 2 chassis × 8 slots, 900 GB/s NVLink-ish, 400 Gbit RoCE.
        Fabric::new(2, 8, 900.0, 400.0)
    }

    #[test]
    fn local_transfer_is_free() {
        let mut f = fabric();
        let a = NodeAddr { chassis: 0, slot: 0 };
        assert_eq!(f.transfer(a, a, 1e9, 5.0).unwrap(), 5.0);
    }

    #[test]
    fn scaleup_faster_than_scaleout() {
        let mut f = fabric();
        let a = NodeAddr { chassis: 0, slot: 0 };
        let b = NodeAddr { chassis: 0, slot: 1 };
        let c = NodeAddr { chassis: 1, slot: 0 };
        let up = f.transfer(a, b, 1e9, 0.0).unwrap();
        let mut f2 = fabric();
        let out = f2.transfer(a, c, 1e9, 0.0).unwrap();
        assert!(up < out, "scale-up {up} should beat scale-out {out}");
    }

    #[test]
    fn contention_serializes() {
        let mut f = fabric();
        let a = NodeAddr { chassis: 0, slot: 0 };
        let c = NodeAddr { chassis: 1, slot: 0 };
        let t1 = f.transfer(a, c, 5e9, 0.0).unwrap();
        let t2 = f.transfer(a, c, 5e9, 0.0).unwrap();
        assert!(t2 > t1, "second transfer must queue behind the first");
        // 5 GB over 50 GB/s = 0.1 s each (plus latency).
        assert!((t1 - 0.2).abs() < 0.01, "t1={t1}");
        assert!((t2 - 0.3).abs() < 0.01, "t2={t2}");
    }

    #[test]
    fn cross_chassis_kv_transfer_realistic() {
        // §5.2: 70B FP16 @ 4K-token KV ≈ 1.31 GB; over 400 Gbit ≈ 26 ms
        // for each of two NIC hops in this model.
        let mut f = fabric();
        let kv = 4096.0 * 327_680.0;
        let a = NodeAddr { chassis: 0, slot: 0 };
        let c = NodeAddr { chassis: 1, slot: 3 };
        let done = f.transfer(a, c, kv, 0.0).unwrap();
        assert!(done > 0.02 && done < 0.1, "done={done}");
    }

    #[test]
    fn estimate_does_not_reserve() {
        let f2 = fabric();
        let a = NodeAddr { chassis: 0, slot: 0 };
        let c = NodeAddr { chassis: 1, slot: 0 };
        let e1 = f2.estimate(a, c, 1e9, 0.0);
        let e2 = f2.estimate(a, c, 1e9, 0.0);
        assert_eq!(e1, e2);
    }

    #[test]
    fn bad_address_rejected() {
        let mut f = fabric();
        let a = NodeAddr { chassis: 0, slot: 0 };
        let bad = NodeAddr { chassis: 9, slot: 0 };
        assert!(f.transfer(a, bad, 1.0, 0.0).is_err());
    }

    #[test]
    fn reset_clears_reservations() {
        let mut f = fabric();
        let a = NodeAddr { chassis: 0, slot: 0 };
        let c = NodeAddr { chassis: 1, slot: 0 };
        let t1 = f.transfer(a, c, 5e9, 0.0).unwrap();
        f.reset();
        let t2 = f.transfer(a, c, 5e9, 0.0).unwrap();
        assert_eq!(t1, t2, "reset must forget prior reservations");
        assert_eq!(f.carried().1, 1e10); // only the post-reset transfer
    }

    #[test]
    fn grow_adds_addressable_chassis() {
        let mut f = fabric();
        let a = NodeAddr { chassis: 0, slot: 0 };
        let c = NodeAddr { chassis: 3, slot: 0 };
        assert!(f.transfer(a, c, 1.0, 0.0).is_err());
        f.grow(4);
        assert_eq!(f.n_chassis, 4);
        assert!(f.transfer(a, c, 1.0, 0.0).is_ok());
        // New links match the old tier's bandwidth.
        let mut f2 = fabric();
        f2.grow(4);
        let t_old = f2.transfer(a, NodeAddr { chassis: 1, slot: 0 }, 1e9, 0.0).unwrap();
        let mut f3 = fabric();
        f3.grow(4);
        let t_new = f3
            .transfer(NodeAddr { chassis: 2, slot: 0 }, NodeAddr { chassis: 3, slot: 0 }, 1e9, 0.0)
            .unwrap();
        assert!((t_old - t_new).abs() < 1e-9);
        // Growing to a smaller size is a no-op.
        f.grow(2);
        assert_eq!(f.n_chassis, 4);
    }

    #[test]
    fn transfer_clock_matches_raw_fabric() {
        // The clock is the same FIFO reservation model at chassis
        // granularity: identical completion times, identical contention.
        let mut raw = fabric();
        let mut clock = TransferClock::new(fabric());
        let a = NodeAddr { chassis: 0, slot: 0 };
        let c = NodeAddr { chassis: 1, slot: 0 };
        for i in 0..3 {
            let t_raw = raw.transfer(a, c, 5e9, i as f64 * 0.01).unwrap();
            let t_clk = clock.transfer(0, 1, 5e9, i as f64 * 0.01).unwrap();
            assert_eq!(t_raw, t_clk, "hop {i}");
        }
        assert_eq!(raw.carried(), clock.carried());
        // Same-chassis hops are free, bad chassis rejected, grow works.
        assert_eq!(clock.transfer(1, 1, 1e9, 7.0).unwrap(), 7.0);
        assert!(clock.transfer(0, 9, 1.0, 0.0).is_err());
        clock.grow(10);
        assert_eq!(clock.n_chassis(), 10);
        assert!(clock.transfer(0, 9, 1.0, 0.0).is_ok());
        // Estimate does not reserve; reset forgets reservations.
        let e1 = clock.estimate(0, 1, 1e9, 100.0);
        assert_eq!(e1, clock.estimate(0, 1, 1e9, 100.0));
        clock.reset();
        assert_eq!(clock.carried(), (0.0, 0.0));
    }

    #[test]
    fn shared_clock_matches_raw_clock() {
        // The shared handle is the same reservation model — identical
        // completion times and carried bytes for an identical schedule.
        let mut raw = TransferClock::new(fabric());
        let shared = SharedTransferClock::new(fabric());
        for i in 0..4 {
            let t_raw = raw.transfer(0, 1, 5e9, i as f64 * 0.01).unwrap();
            let t_shr = shared.transfer(0, 1, 5e9, i as f64 * 0.01).unwrap();
            assert_eq!(t_raw, t_shr, "hop {i}");
        }
        assert_eq!(raw.carried(), shared.carried());
        assert_eq!(shared.transfer(1, 1, 1e9, 3.0).unwrap(), 3.0);
        assert!(shared.transfer(0, 9, 1.0, 0.0).is_err());
        shared.grow(4);
        assert_eq!(shared.n_chassis(), 4);
        let e = shared.estimate(0, 1, 1e9, 50.0);
        assert_eq!(e, shared.estimate(0, 1, 1e9, 50.0), "estimate must not reserve");
        shared.reset();
        assert_eq!(shared.carried(), (0.0, 0.0));
    }

    #[test]
    fn shared_clock_serializes_concurrent_reservations() {
        // N threads race one link at now=0: FIFO reservation must hand
        // out N distinct, strictly increasing completion slots with no
        // lost updates — the exact set a serial schedule produces.
        let shared = SharedTransferClock::new(fabric());
        let n = 8;
        let mut handles = Vec::new();
        for _ in 0..n {
            let clk = shared.clone();
            handles.push(std::thread::spawn(move || {
                clk.transfer(0, 1, 5e9, 0.0).unwrap()
            }));
        }
        let mut done: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        done.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut serial = TransferClock::new(fabric());
        let expect: Vec<f64> = (0..n).map(|_| serial.transfer(0, 1, 5e9, 0.0).unwrap()).collect();
        for (i, (d, e)) in done.iter().zip(expect.iter()).enumerate() {
            assert!((d - e).abs() < 1e-9, "slot {i}: got {d}, want {e}");
        }
        assert_eq!(shared.carried(), serial.carried());
    }

    #[test]
    fn carried_accounting() {
        let mut f = fabric();
        let a = NodeAddr { chassis: 0, slot: 0 };
        let b = NodeAddr { chassis: 0, slot: 1 };
        let c = NodeAddr { chassis: 1, slot: 0 };
        f.transfer(a, b, 100.0, 0.0).unwrap();
        f.transfer(a, c, 50.0, 0.0).unwrap();
        let (up, out) = f.carried();
        assert_eq!(up, 100.0);
        assert_eq!(out, 100.0); // 50 on each NIC
    }
}
