//! KV-cache transfer scheduling over the fabric.
//!
//! §5.2: "state transfer latency can often be partially amortized by
//! overlapping communication with computation ... KV cache transfers
//! contribute to the latency of the *second token*". The scheduler
//! plans transfers, tracks overlap feasibility (Eqs. 1–2), and reports
//! how much of each transfer was hidden behind decode compute.

use super::fabric::{Fabric, NodeAddr};
use crate::cost::model_profile::ModelProfile;
use crate::Result;

/// A planned KV movement.
#[derive(Debug, Clone)]
pub struct TransferPlan {
    pub from: NodeAddr,
    pub to: NodeAddr,
    pub bytes: f64,
    /// When the prefill finished (transfer may start).
    pub ready_s: f64,
    /// Scheduled completion on the fabric.
    pub done_s: f64,
    /// Portion of transfer time hidden behind the first decode step.
    pub overlapped_s: f64,
    /// Exposed (second-token) latency contribution.
    pub exposed_s: f64,
}

/// Schedules KV transfers with compute overlap.
pub struct TransferScheduler {
    pub fabric: Fabric,
    pub plans: Vec<TransferPlan>,
}

impl TransferScheduler {
    pub fn new(fabric: Fabric) -> TransferScheduler {
        TransferScheduler {
            fabric,
            plans: Vec::new(),
        }
    }

    /// Schedule moving one request's prefix KV (Eq. 3 sizing) from the
    /// prefill node to the decode node. `first_decode_window_s` is the
    /// compute time available for overlap (the first decode step).
    pub fn schedule_kv(
        &mut self,
        m: &ModelProfile,
        isl: u64,
        from: NodeAddr,
        to: NodeAddr,
        ready_s: f64,
        first_decode_window_s: f64,
    ) -> Result<TransferPlan> {
        let bytes = crate::cost::kv::kv_cache_bytes(m, isl, 1);
        let done = self.fabric.transfer(from, to, bytes, ready_s)?;
        let duration = done - ready_s;
        let overlapped = duration.min(first_decode_window_s);
        let plan = TransferPlan {
            from,
            to,
            bytes,
            ready_s,
            done_s: done,
            overlapped_s: overlapped,
            exposed_s: (duration - overlapped).max(0.0),
        };
        self.plans.push(plan.clone());
        Ok(plan)
    }

    /// Aggregate exposed latency across all planned transfers.
    pub fn total_exposed_s(&self) -> f64 {
        self.plans.iter().map(|p| p.exposed_s).sum()
    }

    /// Fraction of transferred bytes whose latency was fully hidden.
    pub fn fully_overlapped_fraction(&self) -> f64 {
        if self.plans.is_empty() {
            return 1.0;
        }
        let hidden = self
            .plans
            .iter()
            .filter(|p| p.exposed_s <= 1e-9)
            .count() as f64;
        hidden / self.plans.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::model_profile::llama3_8b;
    use crate::cost::Precision;
    use crate::transport::fabric::Fabric;

    fn sched() -> TransferScheduler {
        TransferScheduler::new(Fabric::new(2, 8, 900.0, 400.0))
    }

    #[test]
    fn transfer_fully_overlapped_when_window_large() {
        let mut s = sched();
        let m = llama3_8b(Precision::Fp16);
        let plan = s
            .schedule_kv(
                &m,
                512,
                NodeAddr { chassis: 0, slot: 0 },
                NodeAddr { chassis: 1, slot: 0 },
                0.0,
                0.050, // 50 ms decode window
            )
            .unwrap();
        // 512 tok × 131072 B = 67 MB; 2 hops @ 50 GB/s ≈ 2.7 ms « 50 ms.
        assert!(plan.exposed_s < 1e-9, "exposed {}", plan.exposed_s);
        assert_eq!(s.fully_overlapped_fraction(), 1.0);
    }

    #[test]
    fn transfer_exposed_when_window_small() {
        let mut s = sched();
        let m = llama3_8b(Precision::Fp16);
        let plan = s
            .schedule_kv(
                &m,
                32_768, // 4.3 GB KV
                NodeAddr { chassis: 0, slot: 0 },
                NodeAddr { chassis: 1, slot: 0 },
                0.0,
                0.010,
            )
            .unwrap();
        assert!(plan.exposed_s > 0.0);
        assert!(s.total_exposed_s() > 0.0);
    }

    #[test]
    fn same_chassis_uses_scaleup() {
        let mut s = sched();
        let m = llama3_8b(Precision::Fp16);
        let a = NodeAddr { chassis: 0, slot: 0 };
        let b = NodeAddr { chassis: 0, slot: 1 };
        let plan = s.schedule_kv(&m, 4096, a, b, 0.0, 0.0).unwrap();
        // 537 MB over 900 GB/s ≈ 0.6 ms.
        assert!(plan.done_s < 0.002, "done {}", plan.done_s);
    }

    #[test]
    fn plans_accumulate() {
        let mut s = sched();
        let m = llama3_8b(Precision::Fp16);
        let a = NodeAddr { chassis: 0, slot: 0 };
        let b = NodeAddr { chassis: 1, slot: 0 };
        for i in 0..5 {
            s.schedule_kv(&m, 1024, a, b, i as f64 * 0.01, 0.005).unwrap();
        }
        assert_eq!(s.plans.len(), 5);
    }
}
