//! RDMA transport layer model (paper §4.1 "RDMA Transport Layer",
//! §5.2 "Deployment requirements and considerations").
//!
//! Two fabric tiers, as the paper assumes:
//! * **scale-up** — shared-memory-semantics interconnect confined to a
//!   single chassis ("typically supporting up to 8 accelerators");
//! * **scale-out** — RoCE over commodity Ethernet, connecting chassis
//!   without shared memory, "requiring explicit software coordination".
//!
//! [`fabric`] models topology + per-link contention; [`transfer`]
//! schedules KV-cache movements and computes Eq. 1–2 feasibility.

pub mod fabric;
pub mod transfer;

pub use fabric::{Fabric, LinkId, NodeAddr, SharedTransferClock, TransferClock};
pub use transfer::{TransferPlan, TransferScheduler};
