//! IR integration: textual round-trips under randomized graphs, pass
//! pipeline invariants (semantic preservation proxies), and parser
//! robustness against malformed input.

use agentic_hetero::ir::attr::Attr;
use agentic_hetero::ir::parser::parse;
use agentic_hetero::ir::passes::cleanup::Dce;
use agentic_hetero::ir::passes::{Pass, PassManager};
use agentic_hetero::ir::printer::print;
use agentic_hetero::ir::verifier::verify;
use agentic_hetero::ir::{Graph, GraphBuilder};
use agentic_hetero::util::prop;
use agentic_hetero::util::rng::Rng;

/// Random linear-ish agent graph: a chain with occasional fan-out,
/// drawn from the user-facing (pre-decomposition) op set.
fn random_graph(rng: &mut Rng) -> Graph {
    let ops = [
        "llm.infer",
        "tool.call",
        "mem.lookup",
        "gp.compute",
        "ctrl.plan",
        "stt.transcribe",
        "tts.synthesize",
    ];
    let mut b = GraphBuilder::new("random");
    let mut values = vec![b.op("io.input", &[])];
    let n = rng.index(12) + 1;
    for _ in 0..n {
        let op = *rng.choose(&ops);
        let src = *rng.choose(&values);
        let v = match op {
            "llm.infer" => b.op_with(
                op,
                &[src],
                &[
                    ("model", Attr::Str("8b-fp16".into())),
                    ("isl", Attr::Int(rng.range(16, 2048) as i64)),
                    ("osl", Attr::Int(rng.range(8, 512) as i64)),
                ],
            ),
            "tool.call" => b.op_with(op, &[src], &[("tool", Attr::Str("search".into()))]),
            "gp.compute" => b.op_with(op, &[src], &[("op", Attr::Str("fmt".into()))]),
            _ => b.op(op, &[src]),
        };
        values.push(v);
    }
    let out = *values.last().unwrap();
    b.op("io.output", &[out]);
    b.output(out);
    b.finish()
}

#[test]
fn random_graphs_roundtrip_and_verify() {
    prop::check("ir-roundtrip", |rng| {
        let g = random_graph(rng);
        verify(&g).expect("generated graph verifies");
        let text = print(&g);
        let g2 = parse(&text).expect("round-trip parse");
        verify(&g2).expect("parsed graph verifies");
        assert_eq!(print(&g2), text, "print∘parse must be a fixpoint");
        assert_eq!(g2.size(), g.size());
    });
}

#[test]
fn pipeline_preserves_io_and_verification() {
    prop::check("ir-pipeline-invariants", |rng| {
        let g = random_graph(rng);
        let n_llm = g.op_names().iter().filter(|o| *o == "llm.infer").count();
        let n_tools = g.op_names().iter().filter(|o| *o == "tool.call").count();
        let mut lowered = g.clone();
        PassManager::standard().run(&mut lowered).expect("pipeline");
        verify(&lowered).expect("lowered verifies");

        let names = lowered.op_names();
        // Decomposition is total: no coarse ops survive...
        assert!(!lowered.contains_op("llm.infer"));
        assert!(!lowered.contains_op("tool.call"));
        // ...and is conservative: every decomposed stage appears (unless
        // it was dead and DCE removed the whole chain, which cannot
        // happen here because the chain feeds io.output).
        let live_prefills = names.iter().filter(|o| *o == "llm.prefill").count();
        let live_lookups = names.iter().filter(|o| *o == "tool.lookup").count();
        // Dead branches may prune some, never create extras.
        assert!(live_prefills <= n_llm);
        assert!(live_lookups <= n_tools);
        // The output boundary survives everything.
        assert!(lowered.contains_op("io.output"));
        // Every surviving LLM stage carries cost annotation.
        for node in &lowered.nodes {
            if node.op == "llm.prefill" || node.op == "llm.decode" {
                assert!(node.attr("wl_class").is_some(), "missing annotation");
                assert!(node.attr("est_flops").is_some());
            }
        }
    });
}

#[test]
fn dce_never_removes_live_code() {
    prop::check("ir-dce-liveness", |rng| {
        let g = random_graph(rng);
        let mut pruned = g.clone();
        Dce.run(&mut pruned).unwrap();
        verify(&pruned).unwrap();
        // The value feeding io.output still has a producer chain back to
        // io.input: check by re-verifying SSA + output op presence.
        assert!(pruned.contains_op("io.output"));
        assert!(pruned.contains_op("io.input"));
        // Idempotence.
        let mut again = pruned.clone();
        let changed = Dce.run(&mut again).unwrap();
        assert!(!changed, "DCE must reach a fixpoint in one run");
    });
}

#[test]
fn parser_rejects_garbage_without_panicking() {
    let cases = [
        "",
        "graph",
        "graph @g(",
        "graph @g() { %0 = }",
        "graph @g() { %0 = op(%1 }",
        "graph @g() { yield %0 yield %1 }",
        "graph @g() { %0 = io.input() } trailing",
        "graph @g() { %0 = io.input() {k = } }",
        "graph @g() { %0 = io.input() {k = \"unterminated} }",
        "graph @g() {{}}",
        "not even close",
        "graph @g() { %999999999999999999999 = io.input() }",
    ];
    for src in cases {
        let r = parse(src);
        assert!(r.is_err(), "should reject: {src:?}");
    }
}

#[test]
fn parser_fuzz_never_panics() {
    // Mutate valid IR text randomly; the parser must return Err or Ok,
    // never panic (catch_unwind guards the claim).
    prop::check_cases("ir-parser-fuzz", 256, &mut |rng: &mut Rng| {
        let g = random_graph(rng);
        let mut text: Vec<u8> = print(&g).into_bytes();
        let mutations = rng.index(8);
        for _ in 0..mutations {
            if text.is_empty() {
                break;
            }
            let i = rng.index(text.len());
            match rng.index(3) {
                0 => {
                    text[i] = rng.range(32, 127) as u8;
                }
                1 => {
                    text.remove(i);
                }
                _ => {
                    let c = rng.range(32, 127) as u8;
                    text.insert(i, c);
                }
            }
        }
        if let Ok(s) = String::from_utf8(text) {
            let _ = parse(&s); // must not panic
        }
    });
}

#[test]
fn deep_nesting_round_trips() {
    // 6 levels of nested supervisors.
    fn nest(depth: usize) -> Graph {
        let mut b = GraphBuilder::new(&format!("level{depth}"));
        let x = b.op("io.input", &[]);
        let v = if depth == 0 {
            b.op_with("llm.infer", &[x], &[("model", "8b-fp16".into())])
        } else {
            b.region_op("agent.graph", &[x], &[], nest(depth - 1))
        };
        b.output(v);
        b.finish()
    }
    let g = nest(6);
    verify(&g).unwrap();
    let text = print(&g);
    let g2 = parse(&text).unwrap();
    verify(&g2).unwrap();
    assert_eq!(print(&g2), text);
    assert_eq!(g2.size(), g.size());
}
