//! Disabled tracing must be free: `record_with` on an absent sink may
//! not run its closure, and therefore may not allocate. Pinned with a
//! counting global allocator, which is why this lives in its own
//! integration-test binary (one `#[global_allocator]` per binary, and a
//! single #[test] so no parallel test pollutes the counter).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use agentic_hetero::obs::trace::{record_with, Span, SpanKind, TraceSink};

struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn span(i: u64) -> Span {
    Span {
        request: i,
        node: 0,
        kind: SpanKind::Host,
        // Per-span heap work the disabled path must never do.
        group: format!("group-{i}"),
        chassis: 0,
        t_start: i as f64,
        t_end: i as f64 + 1.0,
        parent: -1,
        queue_wait: 0.0,
    }
}

#[test]
fn disabled_tracing_allocates_nothing() {
    // Phase 1: tracing off. The closure builds a Span with a formatted
    // String, so *any* evaluation shows up in the allocation counter.
    let off: Option<Arc<TraceSink>> = None;
    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for i in 0..10_000u64 {
        record_with(&off, || span(i));
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "disabled tracing must not allocate (the span closure ran)"
    );

    // Phase 2 (control): with a sink attached the same loop must both
    // allocate and record — proving the counter actually observes the
    // instrumentation path and phase 1 isn't vacuous.
    let sink = TraceSink::new();
    let on = Some(Arc::clone(&sink));
    let before = ALLOCATIONS.load(Ordering::SeqCst);
    for i in 0..100u64 {
        record_with(&on, || span(i));
    }
    let after = ALLOCATIONS.load(Ordering::SeqCst);
    assert!(after > before, "enabled tracing allocates spans");
    assert_eq!(sink.len(), 100);
}
