//! Cross-backend conformance: the same `ExecutionPlan` + workload run
//! through the DAG **simulator** (`cluster/dag.rs`, modeled time) and
//! the **live server** (`server/`, wall-clock on the synthetic engine +
//! host pool) must agree on the execution structure:
//!
//! * per-role request counts match **exactly** (every binding of every
//!   request runs exactly once, on the stage kind the plan bound);
//! * per-stage latency orderings agree (slow tool stages dominate fast
//!   IO stages; decode dominates prefill) — the backends measure
//!   different clocks, so orderings, not absolute values, must match;
//! * both backends report per-role utilization from the same plan, in
//!   range, with the same busy-share ordering.
//!
//! Since the multi-engine refactor the live runtime schedules each LLM
//! phase onto the engine its role's pipeline group is bound to, and the
//! fused prefill→decode KV handoff is charged as a real timed transfer
//! over the **same contended clock** the simulator prices
//! (`transport::fabric::TransferClock`). That upgrades this suite from
//! "latency orderings agree" to a bounded cross-chassis latency
//! comparison: on a plan whose hop cost dominates, live end-to-end
//! latency (converted to modeled seconds via the time scale) must not
//! undercut the simulator's prediction, and per-request KV-hop bytes
//! must match the plan's `LlmUnit` placement exactly
//! ([`cross_chassis_live_does_not_undercut_sim`], which also writes
//! `CONFORMANCE_cross_chassis.json` — the per-stage latency report CI
//! uploads next to the bench ledgers).
//!
//! Gated off pjrt builds: the live side runs on the synthetic engine.

#![cfg(not(feature = "pjrt"))]

use std::sync::mpsc;
use std::thread;
use std::time::{Duration, Instant};

use agentic_hetero::cluster::dag::DagSim;
use agentic_hetero::cluster::trace::{generate, TraceConfig};
use agentic_hetero::plan::{
    AdmissionPolicy, BatchPolicy, ExecutionPlan, FabricSpec, NodeBinding, PipelineBinding,
    Role, SlaSpec, Stage,
};
use agentic_hetero::runtime::Engine;
use agentic_hetero::server::{ChatRequest, ChatResponse, Server};

fn cpu(op: &str, latency_s: f64, deps: Vec<usize>) -> NodeBinding {
    NodeBinding {
        op: op.into(),
        class: "CPU".into(),
        stage: Stage::Cpu,
        latency_s,
        cost_usd: 0.0,
        deps,
        xfer_bytes: 0.0,
        token_fraction: 1.0,
        prefix_overlap: 0.0,
    }
}

fn llm(op: &str, stage: Stage, latency_s: f64, deps: Vec<usize>) -> NodeBinding {
    NodeBinding {
        op: op.into(),
        class: "H100".into(),
        stage,
        latency_s,
        cost_usd: 1e-5,
        deps,
        xfer_bytes: 1e6,
        token_fraction: 1.0,
        prefix_overlap: 0.0,
    }
}

/// A two-inference voice/supervisor agent: STT → LLM → tool → LLM → TTS.
/// Nine bindings, five on the host pool, two prefill+decode pairs.
fn conformance_plan() -> ExecutionPlan {
    ExecutionPlan {
        agent: "conformance_agent".into(),
        model: "8b-fp16".into(),
        sla: SlaSpec::EndToEnd(60.0),
        bindings: vec![
            cpu("io.input", 0.0002, vec![]),            // 0
            cpu("stt.transcribe", 0.02, vec![0]),       // 1
            llm("llm.prefill", Stage::LlmPrefill, 0.03, vec![1]), // 2
            llm("llm.decode", Stage::LlmDecode, 0.3, vec![2]),    // 3
            cpu("tool.search", 0.06, vec![3]),          // 4
            llm("llm.prefill", Stage::LlmPrefill, 0.03, vec![4]), // 5
            llm("llm.decode", Stage::LlmDecode, 0.3, vec![5]),    // 6
            cpu("tts.synthesize", 0.02, vec![6]),       // 7
            cpu("io.output", 0.0005, vec![7]),          // 8
        ],
        pipelines: vec![
            PipelineBinding {
                role: Role::Prefill,
                device: "H100".into(),
                tp: 1,
                pp: 1,
                max_batch: 8,
                replicas: 1,
                chassis: 0,
            },
            PipelineBinding {
                role: Role::Decode,
                device: "H100".into(),
                tp: 1,
                pp: 1,
                max_batch: 32,
                replicas: 2,
                chassis: 1,
            },
        ],
        batching: BatchPolicy::default(),
        admission: AdmissionPolicy::default(),
        fabric: FabricSpec::default(),
        cpu_workers: 4,
        cost_usd: 5e-5,
        latency_s: 0.8,
        pass_log: vec![],
    }
}

const N_REQ: usize = 24;
const ISL: usize = 64;
const OSL: usize = 16;

fn sim_trace() -> Vec<agentic_hetero::cluster::trace::Request> {
    generate(&TraceConfig {
        n_requests: N_REQ,
        rate: 50.0,
        isl_mean: ISL as u64,
        osl_mean: OSL as u64,
        sigma: 0.0,
        seed: 5,
    })
}

fn live_requests(agent: &str) -> Vec<ChatRequest> {
    (0..N_REQ as u64)
        .map(|i| {
            let byte = b'a' + (i % 23) as u8;
            ChatRequest::new(i, vec![byte; ISL], OSL).with_agent(agent)
        })
        .collect()
}

/// Run the live workload on its own thread with a deadlock watchdog.
fn run_live(mut server: Server, reqs: Vec<ChatRequest>) -> (Server, Vec<ChatResponse>) {
    let (done_tx, done_rx) = mpsc::channel();
    let handle = thread::spawn(move || {
        let out = server.run_workload(reqs);
        let _ = done_tx.send(());
        (server, out)
    });
    match done_rx.recv_timeout(Duration::from_secs(60)) {
        Ok(()) => {
            let (server, out) = handle.join().expect("serve thread panicked");
            (server, out.expect("live serve must not error"))
        }
        Err(_) => panic!("live DAG execution deadlocked (watchdog fired)"),
    }
}

/// Mean execution-span duration of live stages matching `op`.
fn live_mean_span(responses: &[ChatResponse], op: &str) -> f64 {
    let durs: Vec<f64> = responses
        .iter()
        .flat_map(|r| r.stages.iter())
        .filter(|s| s.op == op)
        .map(|s| s.duration_s())
        .collect();
    assert!(!durs.is_empty(), "no live spans for op {op}");
    durs.iter().sum::<f64>() / durs.len() as f64
}

/// Mean live span duration over all stages with the given role.
fn live_mean_role(responses: &[ChatResponse], role: &str) -> f64 {
    let durs: Vec<f64> = responses
        .iter()
        .flat_map(|r| r.stages.iter())
        .filter(|s| s.role == role)
        .map(|s| s.duration_s())
        .collect();
    assert!(!durs.is_empty(), "no live spans for role {role}");
    durs.iter().sum::<f64>() / durs.len() as f64
}

#[test]
fn sim_and_live_agree_on_dag_execution() {
    let plan = conformance_plan();

    // ---- simulator backend ------------------------------------------
    let trace = sim_trace();
    let mut sim = DagSim::new(&plan).unwrap();
    let report = sim.run(&trace).unwrap();
    let detail = sim.last_detail().expect("run populates detail").clone();

    assert_eq!(report.n_requests, N_REQ);
    // Two decode bindings per request, OSL tokens each.
    assert_eq!(report.output_tokens, (N_REQ * 2 * OSL) as u64);

    // ---- live backend -----------------------------------------------
    let mut server = Server::from_plan(Engine::synthetic_default(), &plan).unwrap();
    let mut cfg = server.config().clone();
    cfg.time_scale = 0.05; // 60 ms tool stage → 3 ms wall sleep
    cfg.max_new_tokens = OSL;
    server.reconfigure(cfg);
    server.install_plan(&plan).unwrap();

    let t0 = Instant::now();
    let (mut server, responses) = run_live(server, live_requests(&plan.agent));
    let wall = t0.elapsed().as_secs_f64();

    assert_eq!(responses.len(), N_REQ);
    let mut live_tokens = 0u64;
    for r in &responses {
        assert!(r.is_ok(), "request {} failed: {:?}", r.id, r.error);
        assert_eq!(
            r.stages.len(),
            plan.bindings.len(),
            "every plan binding must execute exactly once"
        );
        assert!(r.e2e_s >= r.ttft_s);
        live_tokens += r.tokens as u64;
        // Dependency order holds stage-by-stage.
        for s in &r.stages {
            for &d in &plan.bindings[s.node].deps {
                let dep = r.stages.iter().find(|x| x.node == d).unwrap();
                assert!(
                    dep.end_s <= s.start_s + 1e-9,
                    "node {} started before dep {} finished",
                    s.node,
                    d
                );
            }
        }
    }

    // ---- per-role request counts match exactly ----------------------
    assert_eq!(detail.host_jobs, (N_REQ * 5) as u64);
    assert_eq!(detail.prefill_jobs, (N_REQ * 2) as u64);
    assert_eq!(detail.decode_jobs, (N_REQ * 2) as u64);
    let snap = server.metrics.snapshot();
    assert_eq!(snap["server_host_jobs"], detail.host_jobs as f64);
    assert_eq!(snap["server_prefill_jobs"], detail.prefill_jobs as f64);
    assert_eq!(snap["server_decode_jobs"], detail.decode_jobs as f64);

    // ---- token parity: both backends generate the same stream -------
    assert_eq!(live_tokens, report.output_tokens);

    // ---- KV-hop parity: the live fused prefill→decode handoffs move
    // exactly the bytes the simulator priced over the fabric ----------
    let live_kv: f64 = responses.iter().map(|r| r.kv_hop_bytes).sum();
    assert!(
        (live_kv - report.kv_bytes_moved).abs() < 1.0,
        "live KV hops {live_kv} vs sim {}",
        report.kv_bytes_moved
    );

    // ---- per-stage latency orderings agree --------------------------
    // Simulator: mean sojourn per binding index.
    let sim_lat = &detail.node_mean_latency_s;
    assert!(
        sim_lat[4] > sim_lat[0],
        "sim: tool.search ({}) must dominate io.input ({})",
        sim_lat[4],
        sim_lat[0]
    );
    assert!(
        sim_lat[3] > sim_lat[2],
        "sim: decode must dominate prefill"
    );
    // Live: mean execution span per op/role.
    assert!(
        live_mean_span(&responses, "tool.search") > live_mean_span(&responses, "io.input"),
        "live: tool.search must dominate io.input"
    );
    assert!(
        live_mean_role(&responses, "llm_decode") > live_mean_role(&responses, "llm_prefill"),
        "live: decode must dominate prefill"
    );

    // ---- per-role utilization from the same plan --------------------
    assert!(report.prefill_utilization > 0.0 && report.prefill_utilization <= 1.0);
    assert!(report.decode_utilization > 0.0 && report.decode_utilization <= 1.0);
    // Busy-share ordering: decode work dominates prefill in both
    // backends (device-seconds in sim, engine-seconds live).
    let sim_pre_busy = report.prefill_utilization * report.makespan_s; // 1 device
    let sim_dec_busy = report.decode_utilization * 2.0 * report.makespan_s;
    assert!(sim_dec_busy > sim_pre_busy);
    let (live_pre, live_dec, live_host) = server.take_utilization(wall);
    assert!(live_pre > 0.0 && live_pre <= 1.0, "prefill util {live_pre}");
    assert!(live_dec > 0.0 && live_dec <= 1.0, "decode util {live_dec}");
    assert!(live_host > 0.0 && live_host <= 1.0, "host util {live_host}");
    assert!(
        live_dec > live_pre,
        "live decode busy-share ({live_dec}) must dominate prefill ({live_pre})"
    );

    // Host pool never exceeded the plan's capacity.
    assert!(server.host_high_watermark() <= plan.cpu_workers as u64);
}

/// A two-chassis plan built so the prefill→decode KV hop **dominates**
/// end-to-end latency: prefill bound to chassis 0, decode to chassis 1,
/// over a deliberately skinny 0.02 Gbit scale-out link (64-token KV ≈
/// 8.4 MB → seconds of modeled transfer per request, far above every
/// compute stage). Any backend that forgets to charge the hop is off by
/// an order of magnitude.
fn cross_chassis_plan() -> ExecutionPlan {
    ExecutionPlan {
        agent: "hop_agent".into(),
        model: "8b-fp16".into(),
        sla: SlaSpec::None,
        bindings: vec![
            cpu("io.input", 0.0005, vec![]),                      // 0
            llm("llm.prefill", Stage::LlmPrefill, 0.03, vec![0]), // 1
            llm("llm.decode", Stage::LlmDecode, 0.3, vec![1]),    // 2
            cpu("io.output", 0.0005, vec![2]),                    // 3
        ],
        pipelines: vec![
            PipelineBinding {
                role: Role::Prefill,
                device: "H100".into(),
                tp: 1,
                pp: 1,
                max_batch: 8,
                replicas: 1,
                chassis: 0,
            },
            PipelineBinding {
                role: Role::Decode,
                device: "H100".into(),
                tp: 1,
                pp: 1,
                max_batch: 32,
                replicas: 1,
                chassis: 1,
            },
        ],
        batching: BatchPolicy::default(),
        admission: AdmissionPolicy::default(),
        fabric: FabricSpec {
            slots_per_chassis: 8,
            scaleout_gbit: 0.02, // 2.5 MB/s: the hop is the bottleneck
        },
        cpu_workers: 4,
        cost_usd: 3e-5,
        latency_s: 0.4,
        pass_log: vec![],
    }
}

/// Acceptance gate for the cross-chassis fidelity fix: on a plan whose
/// KV hop dominates, live measured latency (in modeled seconds) must
/// not undercut the simulator's prediction, and every request's KV-hop
/// bytes must match the plan's fused `LlmUnit` placement exactly.
/// Writes the per-stage latency report CI uploads
/// (`CONFORMANCE_cross_chassis.json`).
#[test]
fn cross_chassis_live_does_not_undercut_sim() {
    use agentic_hetero::cost::kv::kv_cache_bytes;
    use agentic_hetero::cost::model_profile::by_short_name;
    use agentic_hetero::plan::instance::llm_units;
    use agentic_hetero::util::json::Json;

    const N: usize = 4;
    const HOP_ISL: usize = 64;
    const HOP_OSL: usize = 16;
    const TIME_SCALE: f64 = 0.02;

    let plan = cross_chassis_plan();
    // The plan fuses exactly one prefill+decode unit per request, bound
    // to different chassis — the hop the live path must now charge.
    let (units, _) = llm_units(&plan);
    assert_eq!(units.len(), 1);
    assert_eq!(units[0].prefill, Some(1));
    assert_eq!(units[0].decode, Some(2));

    // ---- simulator prediction ---------------------------------------
    let trace = generate(&TraceConfig {
        n_requests: N,
        rate: 100.0,
        isl_mean: HOP_ISL as u64,
        osl_mean: HOP_OSL as u64,
        sigma: 0.0,
        seed: 3,
    });
    let mut sim = DagSim::new(&plan).unwrap();
    let report = sim.run(&trace).unwrap();
    let sim_detail = sim.last_detail().unwrap().clone();
    let m = by_short_name(&plan.model).unwrap();
    let kv_per_req = kv_cache_bytes(&m, HOP_ISL as u64, 1);
    // Sanity: the hop dominates the sim's end-to-end prediction. One
    // NIC hop of 8.4 MB at 2.5 MB/s ≈ 3.4 s; compute stages are ≪ 1 s.
    let one_hop_s = kv_per_req / (plan.fabric.scaleout_gbit * 1e9 / 8.0);
    assert!(one_hop_s > 1.0, "hop must dominate: {one_hop_s}");
    assert!(report.e2e_p50_s > one_hop_s, "sim must charge the hop");
    assert!(
        (report.kv_bytes_moved - N as f64 * kv_per_req).abs() < 1.0,
        "sim hop bytes: {} vs {}",
        report.kv_bytes_moved,
        N as f64 * kv_per_req
    );

    // ---- live measurement (engine pool: one per pipeline group) -----
    let mut server =
        Server::from_plan_with_engines(Engine::synthetic_pool(plan.pipelines.len()), &plan)
            .unwrap();
    assert_eq!(server.engine_count(), 2);
    let mut cfg = server.config().clone();
    cfg.time_scale = TIME_SCALE;
    cfg.max_new_tokens = HOP_OSL;
    server.reconfigure(cfg);
    server.install_plan(&plan).unwrap();
    let reqs: Vec<ChatRequest> = (0..N as u64)
        .map(|i| {
            let byte = b'a' + (i % 23) as u8;
            ChatRequest::new(i, vec![byte; HOP_ISL], HOP_OSL).with_agent(plan.agent.as_str())
        })
        .collect();
    let (_server, responses) = run_live(server, reqs);
    assert_eq!(responses.len(), N);

    // ---- per-request KV-hop bytes match the unit placement exactly --
    for r in &responses {
        assert!(r.is_ok(), "request {} failed: {:?}", r.id, r.error);
        assert!(
            (r.kv_hop_bytes - kv_per_req).abs() < 1.0,
            "request {}: live hop {} vs plan's unit placement {}",
            r.id,
            r.kv_hop_bytes,
            kv_per_req
        );
    }

    // ---- the undercut is gone ---------------------------------------
    // Live wall-clock → modeled seconds via the time scale. Engine
    // compute and batching overheads only *add* live latency; without
    // the charged hop the live figure sits an order of magnitude below
    // the sim's, so a 25% tolerance cleanly separates fixed from broken.
    let live_e2e_modeled: Vec<f64> =
        responses.iter().map(|r| r.e2e_s / TIME_SCALE).collect();
    let live_mean = live_e2e_modeled.iter().sum::<f64>() / N as f64;
    assert!(
        live_mean >= report.e2e_p50_s * 0.75,
        "live ({live_mean:.2}s modeled) undercuts sim ({:.2}s): the \
         cross-chassis KV hop is not being charged",
        report.e2e_p50_s
    );

    // ---- per-stage latency report for the CI conformance gate -------
    let live_stage_means: Vec<Json> = (0..plan.bindings.len())
        .map(|node| {
            let durs: Vec<f64> = responses
                .iter()
                .flat_map(|r| r.stages.iter())
                .filter(|s| s.node == node)
                .map(|s| s.duration_s() / TIME_SCALE)
                .collect();
            Json::Num(durs.iter().sum::<f64>() / durs.len().max(1) as f64)
        })
        .collect();
    let report_json = agentic_hetero::jobj! {
        "plan" => "cross_chassis",
        "requests" => N,
        "time_scale" => TIME_SCALE,
        "kv_hop_bytes_per_request" => kv_per_req,
        "sim_e2e_p50_s" => report.e2e_p50_s,
        "live_e2e_modeled_mean_s" => live_mean,
        "undercut_tolerance" => 0.25f64,
        "sim_node_mean_latency_s" => sim_detail.node_mean_latency_s.clone(),
        "live_node_mean_latency_s" => Json::Arr(live_stage_means),
    };
    // Best-effort artifact (CI uploads it; a read-only checkout must
    // not fail the gate itself).
    let _ = std::fs::write("CONFORMANCE_cross_chassis.json", report_json.pretty());
}

/// Scripted fleet controller for the simulator side of the
/// two-generation conformance run: applies `plan` at window `at`.
struct ApplyOnce {
    at: usize,
    window: usize,
    plan: ExecutionPlan,
    applied: Vec<agentic_hetero::cluster::dag::FleetChangeStats>,
}

impl agentic_hetero::cluster::dag::FleetController for ApplyOnce {
    fn on_window(
        &mut self,
        _stats: &agentic_hetero::cluster::dag::WindowStats,
    ) -> Option<ExecutionPlan> {
        let w = self.window;
        self.window += 1;
        (w == self.at).then(|| self.plan.clone())
    }

    fn on_applied(&mut self, _t: f64, stats: &agentic_hetero::cluster::dag::FleetChangeStats) {
        self.applied.push(stats.clone());
    }
}

/// The group-granular rebalancing conformance gate: a two-generation
/// decode fleet (H100 + A100) takes a cross-group rebalance diff
/// mid-workload on BOTH backends — the simulator via a controlled fleet
/// change, the live server via `reconfigure_plan` between windows —
/// and afterwards the per-group request counts match **exactly**
/// (`DagDetail::jobs_by_group` vs the `server_group_jobs:*` counters),
/// the retired generation's pipelines drain without dropping a single
/// in-flight request, and token totals stay identical.
#[test]
fn two_generation_rebalance_keeps_per_group_parity() {
    use agentic_hetero::orchestrator::rebalance;
    use agentic_hetero::plan::presets::mixed_generation;
    use agentic_hetero::plan::{PlanDiff, Role};

    const N: usize = 24;
    const MG_ISL: usize = 48;
    const MG_OSL: usize = 16;

    let plan_a = mixed_generation("8b-fp16", "H100", "A100", 1, 2);
    let a100_key = plan_a.pipelines[2].shape_key();
    let h100_key = plan_a.pipelines[1].shape_key();
    // The rebalance under test: one replica moves A100 → H100.
    let plan_b = rebalance(&plan_a, Role::Decode, &a100_key, &h100_key, 1);
    let diff = PlanDiff::between(&plan_a, &plan_b);
    assert!(diff.is_cross_group(), "{}", diff.summary());

    // ---- simulator: the rebalance lands mid-run ---------------------
    let trace = generate(&TraceConfig {
        n_requests: N,
        rate: 40.0,
        isl_mean: MG_ISL as u64,
        osl_mean: MG_OSL as u64,
        sigma: 0.0,
        seed: 17,
    });
    let mut sim = DagSim::new(&plan_a).unwrap();
    let mut ctl = ApplyOnce {
        at: 0,
        window: 0,
        plan: plan_b.clone(),
        applied: Vec::new(),
    };
    let report = sim.run_controlled(&trace, 0.2, &mut ctl).unwrap();
    assert_eq!(report.n_requests, N, "the retiring group must drain, not drop");
    assert_eq!(ctl.applied.len(), 1, "the rebalance must apply");
    assert!(ctl.applied[0].activated >= 1, "H100 capacity comes up");
    assert!(ctl.applied[0].retired >= 1, "A100 capacity drains");
    let detail = sim.last_detail().unwrap().clone();
    // Structural per-group ledger: one prefill + each decode sibling
    // per request, attributed to its generation's group.
    let expect: Vec<(&str, u64)> = vec![
        ("prefill H100 tp1 pp1 b8", N as u64),
        ("decode H100 tp1 pp1 b16", N as u64),
        ("decode A100 tp1 pp1 b16", N as u64),
    ];
    for (key, n) in &expect {
        assert_eq!(
            detail.jobs_by_group.get(*key),
            Some(n),
            "sim group ledger for {key}: {:?}",
            detail.jobs_by_group
        );
    }

    // ---- live server: same plans, same rebalance boundary -----------
    let mut server = Server::from_plan_with_engines(
        Engine::synthetic_pool(plan_a.pipelines.len()),
        &plan_a,
    )
    .unwrap();
    let mut cfg = server.config().clone();
    cfg.time_scale = 0.02;
    cfg.max_new_tokens = MG_OSL;
    server.reconfigure(cfg);
    server.install_plan(&plan_a).unwrap();
    let reqs: Vec<ChatRequest> = (0..N as u64)
        .map(|i| {
            let byte = b'a' + (i % 23) as u8;
            ChatRequest::new(i, vec![byte; MG_ISL], MG_OSL).with_agent(plan_a.agent.as_str())
        })
        .collect();
    let first: Vec<ChatRequest> = reqs[..N / 2].to_vec();
    let second: Vec<ChatRequest> = reqs[N / 2..].to_vec();
    let (mut server, r1) = run_live(server, first);
    // The cross-group rebalance applies between windows, exactly like
    // the orchestrator's live backend.
    server
        .reconfigure_plan(&plan_b)
        .expect("rebalanced plan must install live");
    let (server, r2) = run_live(server, second);
    let responses: Vec<ChatResponse> = r1.into_iter().chain(r2).collect();
    assert_eq!(responses.len(), N);
    let mut live_tokens = 0u64;
    for r in &responses {
        assert!(r.is_ok(), "request {} failed: {:?}", r.id, r.error);
        assert_eq!(
            r.stages.len(),
            plan_a.bindings.len(),
            "every binding executes exactly once across the rebalance"
        );
        live_tokens += r.tokens as u64;
    }

    // ---- per-group request counts match exactly ---------------------
    let snap = server.metrics.snapshot();
    for (key, n) in &expect {
        assert_eq!(
            snap.get(&format!("server_group_jobs:{key}")).copied(),
            Some(*n as f64),
            "live group counter for {key}"
        );
    }
    // And the aggregate role counters still agree with the sim.
    assert_eq!(snap["server_prefill_jobs"], detail.prefill_jobs as f64);
    assert_eq!(snap["server_decode_jobs"], detail.decode_jobs as f64);
    assert_eq!(snap["server_host_jobs"], detail.host_jobs as f64);

    // ---- token parity across the rebalance --------------------------
    assert_eq!(live_tokens, report.output_tokens);
}

/// The unified-tracing conformance gate: the same plan run through both
/// backends must emit **structurally identical span trees** — one
/// envelope per request plus exactly one execution span per binding,
/// with the same kinds, the same gating parents, and the same pipeline
/// group keys. The plan is pinned to one chassis so neither backend
/// emits KV-transfer spans and the tree is fully deterministic (every
/// node is single-dep, so the gating edge *is* the dep). On top of the
/// structure, the critical-path attribution must explain each request's
/// e2e exactly (buckets sum to e2e) on both backends.
#[test]
fn sim_and_live_emit_matching_span_trees() {
    use agentic_hetero::obs::critical_path::{attribute_all, BUCKETS};
    use agentic_hetero::obs::trace::{classify_host_op, Span, SpanKind, TraceSink};
    use std::collections::{BTreeMap, BTreeSet};
    use std::sync::Arc;

    let mut plan = conformance_plan();
    plan.pipelines[1].chassis = 0; // same chassis: no fabric hops → no KV spans

    let prefill_key = plan.pipelines[0].shape_key();
    let decode_key = plan.pipelines[1].shape_key();

    // The expected span tree of one request, derived from the plan:
    // (node, kind, gating parent, group).
    let expected: BTreeSet<(i64, &'static str, i64, String)> = plan
        .bindings
        .iter()
        .enumerate()
        .map(|(n, b)| {
            let (kind, group) = match b.stage {
                Stage::LlmPrefill => (SpanKind::Prefill, prefill_key.clone()),
                Stage::LlmDecode => (SpanKind::Decode, decode_key.clone()),
                _ => (classify_host_op(&b.op), "host".to_string()),
            };
            let parent = b.deps.first().map(|&d| d as i64).unwrap_or(-1);
            (n as i64, kind.as_str(), parent, group)
        })
        .collect();

    let check_tree = |backend: &str, spans: &[Span]| {
        let mut by_req: BTreeMap<u64, Vec<&Span>> = BTreeMap::new();
        for s in spans {
            by_req.entry(s.request).or_default().push(s);
        }
        assert_eq!(by_req.len(), N_REQ, "{backend}: every request must trace");
        for (req, spans) in by_req {
            let envelopes: Vec<&&Span> = spans
                .iter()
                .filter(|s| s.kind == SpanKind::Request)
                .collect();
            assert_eq!(envelopes.len(), 1, "{backend} req {req}: one envelope");
            let env = envelopes[0];
            assert_eq!(env.node, -1, "{backend} req {req}");
            assert_eq!(env.parent, -1, "{backend} req {req}");
            assert_eq!(env.group, "", "{backend} req {req}");
            assert!(
                !spans.iter().any(|s| s.kind == SpanKind::KvTransfer),
                "{backend} req {req}: same-chassis plan must not emit KV spans"
            );
            let got: BTreeSet<(i64, &str, i64, String)> = spans
                .iter()
                .filter(|s| s.kind != SpanKind::Request)
                .map(|s| (s.node, s.kind.as_str(), s.parent, s.group.clone()))
                .collect();
            assert_eq!(
                got, expected,
                "{backend} req {req}: span tree diverges from the plan"
            );
            // Temporal structure: spans sit inside the envelope and
            // start only after their gating parent finished.
            for s in &spans {
                assert!(s.t_end >= s.t_start - 1e-9, "{backend} req {req}");
                if s.kind == SpanKind::Request {
                    continue;
                }
                assert!(
                    s.t_start >= env.t_start - 1e-6 && s.t_end <= env.t_end + 1e-6,
                    "{backend} req {req} node {}: span outside envelope",
                    s.node
                );
                if s.parent >= 0 {
                    let p = spans
                        .iter()
                        .find(|x| x.node == s.parent && x.kind != SpanKind::Request)
                        .expect("gating parent span exists");
                    assert!(
                        p.t_end <= s.t_start + 1e-6,
                        "{backend} req {req}: node {} started before gating dep {}",
                        s.node,
                        s.parent
                    );
                }
            }
        }
    };

    // ---- simulator backend ------------------------------------------
    let sim_sink = TraceSink::new();
    let mut sim = DagSim::new(&plan).unwrap();
    sim.set_trace_sink(Arc::clone(&sim_sink));
    sim.run(&sim_trace()).unwrap();
    let sim_spans = sim_sink.spans();
    check_tree("sim", &sim_spans);

    // ---- live backend -----------------------------------------------
    let mut server = Server::from_plan(Engine::synthetic_default(), &plan).unwrap();
    let mut cfg = server.config().clone();
    cfg.time_scale = 0.05;
    cfg.max_new_tokens = OSL;
    server.reconfigure(cfg);
    server.install_plan(&plan).unwrap();
    let live_sink = TraceSink::new();
    server.set_trace_sink(Arc::clone(&live_sink));
    let (_server, responses) = run_live(server, live_requests(&plan.agent));
    assert_eq!(responses.len(), N_REQ);
    for r in &responses {
        assert!(r.is_ok(), "request {} failed: {:?}", r.id, r.error);
    }
    let live_spans = live_sink.spans();
    check_tree("live", &live_spans);

    // ---- attribution explains e2e on both backends ------------------
    // Buckets sum to e2e exactly by construction; `coverage` is the
    // honest explicitly-measured share — near-total in the simulator,
    // bounded below on the live path (channel/dispatch gaps between
    // spans land in the implicit queue residual).
    for (backend, spans, min_cov) in
        [("sim", &sim_spans, 0.95), ("live", &live_spans, 0.5)]
    {
        let a = attribute_all(spans);
        assert_eq!(a.requests as usize, N_REQ, "{backend}");
        let bucket_sum: f64 = BUCKETS.iter().map(|b| a.bucket_s(b)).sum();
        assert!(
            (bucket_sum - a.e2e_total_s).abs() <= 1e-6 * a.e2e_total_s.max(1.0),
            "{backend}: buckets ({bucket_sum}) must sum to e2e ({})",
            a.e2e_total_s
        );
        assert!(
            a.min_request_coverage >= min_cov,
            "{backend}: worst-request coverage {} < {min_cov}",
            a.min_request_coverage
        );
        // This plan's decode dominates prefill, and both host-pool
        // buckets see work (stt/tts → host, io/tool → tool_io).
        assert!(a.bucket_s("decode") > a.bucket_s("prefill"), "{backend}");
        assert!(a.bucket_s("host") > 0.0, "{backend}");
        assert!(a.bucket_s("tool_io") > 0.0, "{backend}");
    }
}

#[test]
fn sim_and_live_agree_on_cpu_only_plans() {
    // No LLM stages at all: the host pool carries the whole graph.
    let plan = ExecutionPlan {
        agent: "tools_only".into(),
        model: String::new(),
        sla: SlaSpec::None,
        bindings: vec![
            cpu("io.input", 0.0005, vec![]),
            cpu("tool.lookup", 0.01, vec![0]),
            cpu("io.output", 0.0005, vec![1]),
        ],
        pipelines: vec![],
        batching: BatchPolicy::default(),
        admission: AdmissionPolicy::default(),
        fabric: FabricSpec::default(),
        cpu_workers: 2,
        cost_usd: 0.0,
        latency_s: 0.011,
        pass_log: vec![],
    };
    let trace = generate(&TraceConfig {
        n_requests: 12,
        rate: 100.0,
        isl_mean: 16,
        osl_mean: 4,
        sigma: 0.0,
        seed: 2,
    });
    let mut sim = DagSim::new(&plan).unwrap();
    let report = sim.run(&trace).unwrap();
    let detail = sim.last_detail().unwrap().clone();
    assert_eq!(report.output_tokens, 0);
    assert_eq!(detail.host_jobs, 36);
    assert_eq!(detail.prefill_jobs, 0);

    let mut server = Server::from_plan(Engine::synthetic_default(), &plan).unwrap();
    let mut cfg = server.config().clone();
    cfg.time_scale = 0.1;
    server.reconfigure(cfg);
    server.install_plan(&plan).unwrap();
    let reqs: Vec<ChatRequest> = (0..12u64)
        .map(|i| ChatRequest::new(i, "tooling", 4).with_agent("tools_only"))
        .collect();
    let (server, responses) = run_live(server, reqs);
    assert_eq!(responses.len(), 12);
    for r in &responses {
        assert!(r.is_ok());
        assert_eq!(r.tokens, 0, "no decode stages → no tokens");
        assert_eq!(r.stages.len(), 3);
        // TTFT falls back to completion time, the simulator's rule.
        assert!((r.ttft_s - r.e2e_s).abs() < 1e-9);
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap["server_host_jobs"], 36.0);
    assert_eq!(server.host_capacity(), Some(2));
    assert!(server.host_high_watermark() <= 2);
}

/// The prefix-KV reuse conformance gate: a shared-prefix fan-out plan
/// (one planner inference whose output gates `WORKERS` sibling
/// prefills with identical dependency lists) runs with reuse on and
/// off through BOTH backends. The two sides derive their prefix keys
/// differently — the simulator hashes (request, gating-dep list), the
/// live server hashes the actual context bytes — but both feed the
/// same shared `KvReuse` accounting engine, so on a plan where those
/// equivalence classes coincide the per-group hit/miss ledgers must
/// match **exactly**: per request, the planner prefill is one unique
/// context (a miss) and the fan-out siblings share one (a miss plus
/// `WORKERS - 1` hits). Reuse must also never *increase* prefill work:
/// each backend's reuse-on prefill-token total stays strictly below
/// its reuse-off total, while generated outputs stay byte-identical
/// (live decode re-derives the full context from dep payloads, so only
/// prefill work shrinks).
#[test]
fn prefix_reuse_hit_counts_match_between_backends() {
    use agentic_hetero::cluster::dag::KvReuseConfig;
    use agentic_hetero::plan::presets::shared_prefix_fanout;

    const N: usize = 12;
    const FAN_ISL: usize = 48;
    const FAN_OSL: usize = 8;
    const WORKERS: u64 = 4;

    let plan = shared_prefix_fanout("8b-fp16", "H100", WORKERS as u32);
    let prefill_key = plan.pipelines[0].shape_key();
    let want_hits = N as u64 * (WORKERS - 1);
    let want_misses = N as u64 * 2;

    // ---- simulator: reuse off, then on, same trace ------------------
    let trace = generate(&TraceConfig {
        n_requests: N,
        rate: 50.0,
        isl_mean: FAN_ISL as u64,
        osl_mean: FAN_OSL as u64,
        sigma: 0.0,
        seed: 11,
    });
    let mut sim_off = DagSim::new(&plan).unwrap();
    sim_off.run(&trace).unwrap();
    let d_off = sim_off.last_detail().unwrap().clone();
    assert_eq!(
        d_off.prefix_hits_by_group.values().sum::<u64>(),
        0,
        "reuse off must not touch the prefix ledger"
    );
    let mut sim_on = DagSim::new(&plan).unwrap();
    sim_on.set_kv_reuse(KvReuseConfig::default());
    sim_on.run(&trace).unwrap();
    let d_on = sim_on.last_detail().unwrap().clone();
    assert_eq!(
        d_on.prefix_hits_by_group.get(&prefill_key).copied(),
        Some(want_hits),
        "sim hit ledger: {:?}",
        d_on.prefix_hits_by_group
    );
    assert_eq!(
        d_on.prefix_misses_by_group.get(&prefill_key).copied(),
        Some(want_misses),
        "sim miss ledger: {:?}",
        d_on.prefix_misses_by_group
    );
    assert!(
        d_on.prefill_tokens < d_off.prefill_tokens,
        "sim reuse-on must prefill fewer tokens ({} vs {})",
        d_on.prefill_tokens,
        d_off.prefill_tokens
    );

    // ---- live server: reuse off, then on, same workload -------------
    let run = |reuse: bool| {
        let mut server = Server::from_plan_with_engines(
            Engine::synthetic_pool(plan.pipelines.len()),
            &plan,
        )
        .unwrap();
        let mut cfg = server.config().clone();
        cfg.time_scale = 0.0; // structure, not timing, is under test
        cfg.max_new_tokens = FAN_OSL;
        cfg.kv_reuse = reuse;
        server.reconfigure(cfg);
        server.install_plan(&plan).unwrap();
        // One unique prompt per request: live hashes context *bytes*,
        // so a repeated prompt would alias across requests — a reuse
        // class the per-(request, deps) sim key never forms.
        let reqs: Vec<ChatRequest> = (0..N as u64)
            .map(|i| {
                ChatRequest::new(i, vec![b'a' + i as u8; FAN_ISL], FAN_OSL)
                    .with_agent(plan.agent.as_str())
            })
            .collect();
        let (server, mut responses) = run_live(server, reqs);
        responses.sort_by_key(|r| r.id);
        for r in &responses {
            assert!(r.is_ok(), "request {} failed: {:?}", r.id, r.error);
        }
        assert_eq!(responses.len(), N);
        (server.metrics.snapshot(), responses)
    };
    let (snap_off, resp_off) = run(false);
    let (snap_on, resp_on) = run(true);

    // Reuse off is byte-identical to the pre-feature server: the
    // prefix counters are never even created.
    assert!(
        snap_off.keys().all(|k| !k.starts_with("server_prefix_hits:")
            && !k.starts_with("server_prefix_misses:")),
        "reuse-off serving must not touch prefix counters"
    );

    // ---- per-group hit/miss counts match EXACTLY across backends ----
    for (key, hits) in &d_on.prefix_hits_by_group {
        assert_eq!(
            snap_on.get(&format!("server_prefix_hits:{key}")).copied(),
            Some(*hits as f64),
            "live hit counter for group {key}"
        );
    }
    for (key, misses) in &d_on.prefix_misses_by_group {
        assert_eq!(
            snap_on.get(&format!("server_prefix_misses:{key}")).copied(),
            Some(*misses as f64),
            "live miss counter for group {key}"
        );
    }
    assert_eq!(
        snap_on.get(&format!("server_prefix_hits:{prefill_key}")).copied(),
        Some(want_hits as f64)
    );
    assert_eq!(
        snap_on
            .get(&format!("server_prefix_misses:{prefill_key}"))
            .copied(),
        Some(want_misses as f64)
    );

    // ---- reuse-on never prefills more than reuse-off ----------------
    let live_off = snap_off["server_prefill_tokens"];
    let live_on = snap_on["server_prefill_tokens"];
    assert!(
        live_on < live_off,
        "live reuse-on must prefill fewer tokens ({live_on} vs {live_off})"
    );

    // ---- and the generated streams are byte-identical ---------------
    for (off, on) in resp_off.iter().zip(&resp_on) {
        assert_eq!(off.id, on.id);
        assert_eq!(
            off.output, on.output,
            "request {}: prefix reuse changed the token stream",
            off.id
        );
        assert_eq!(off.tokens, on.tokens);
    }
}

/// Threading must be invisible to conformance: the same mixed-generation
/// workload run with engines on worker threads and with
/// `serialize_engines` (every batch executed inline on the dispatcher
/// thread, the pre-threading behaviour) must produce byte-identical
/// outputs, identical KV-hop accounting, and identical per-group job
/// ledgers. This is the bridge between the sim-vs-live gates above and
/// the worker-thread engine pool: sim == serialized == threaded.
#[test]
fn serialized_and_threaded_dispatch_agree() {
    use agentic_hetero::plan::presets::mixed_generation;

    const N: usize = 24;
    const MG_ISL: usize = 48;
    const MG_OSL: usize = 12;

    let plan = mixed_generation("8b-fp16", "H100", "A100", 1, 2);

    let run = |serialize: bool| {
        let mut server = Server::from_plan_with_engines(
            Engine::synthetic_pool(plan.pipelines.len()),
            &plan,
        )
        .unwrap();
        let mut cfg = server.config().clone();
        cfg.time_scale = 0.0; // structure, not timing, is under test
        cfg.max_new_tokens = MG_OSL;
        cfg.serialize_engines = serialize;
        server.reconfigure(cfg);
        server.install_plan(&plan).unwrap();
        let reqs: Vec<ChatRequest> = (0..N as u64)
            .map(|i| {
                let byte = b'a' + (i % 23) as u8;
                ChatRequest::new(i, vec![byte; MG_ISL], MG_OSL)
                    .with_agent(plan.agent.as_str())
            })
            .collect();
        let (server, mut responses) = run_live(server, reqs);
        responses.sort_by_key(|r| r.id);
        let snap = server.metrics.snapshot();
        let groups: Vec<(String, f64)> = snap
            .iter()
            .filter(|(k, _)| k.starts_with("server_group_jobs:"))
            .map(|(k, v)| (k.clone(), *v))
            .collect();
        (responses, groups)
    };

    let (threaded, threaded_groups) = run(false);
    let (serialized, serialized_groups) = run(true);

    assert_eq!(threaded.len(), N);
    assert_eq!(serialized.len(), N);
    for (t, s) in threaded.iter().zip(&serialized) {
        assert!(t.is_ok(), "threaded request {} failed: {:?}", t.id, t.error);
        assert!(s.is_ok(), "serialized request {} failed: {:?}", s.id, s.error);
        assert_eq!(t.id, s.id);
        assert_eq!(
            t.output, s.output,
            "request {}: threaded dispatch changed the token stream",
            t.id
        );
        assert_eq!(t.tokens, s.tokens);
        assert!(
            (t.kv_hop_bytes - s.kv_hop_bytes).abs() < 1.0,
            "request {}: threaded dispatch changed KV-hop accounting",
            t.id
        );
        assert_eq!(t.stages.len(), s.stages.len());
    }

    // Per-group job ledgers are identical: the same unit landed on the
    // same pipeline group under both dispatch modes.
    assert_eq!(threaded_groups, serialized_groups);
    assert_eq!(
        threaded_groups.len(),
        plan.pipelines.len(),
        "one job counter per pipeline group: {threaded_groups:?}"
    );
}
