//! Serving-stack stress: the threaded dispatcher must sustain 10k+
//! concurrent synthetic requests without dropping, corrupting, or
//! deadlocking anything.
//!
//! The interesting properties at this scale are structural, not
//! timing-based (the release-mode throughput gate lives in
//! `tools/stress_serve.rs`, run by CI):
//!
//! * **zero drops** — every admitted request produces exactly one
//!   response, ids are unique, and none is rejected or failed;
//! * **determinism under load** — responses match what the synthetic
//!   engine produces for the same request run in isolation, proving
//!   batch composition and thread interleaving never leak into token
//!   streams;
//! * **bounded memory** — the host pool's high watermark stays within
//!   the plan's `cpu_workers`, and per-group job counters account for
//!   every request exactly once (nothing duplicated, nothing lost).
//!
//! Both the agent-DAG path (mixed-generation plan: one prefill group +
//! two decode sibling groups on separate engine threads) and the flat
//! path (no plan installed) are stressed.

#![cfg(not(feature = "pjrt"))]

use std::collections::HashSet;
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use agentic_hetero::plan::presets::mixed_generation;
use agentic_hetero::runtime::Engine;
use agentic_hetero::server::{ChatRequest, ChatResponse, Server};

const N_STRESS: usize = 10_000;
const ISL: usize = 24;
const OSL: usize = 4;

/// Run the workload on its own thread with a deadlock watchdog: a hung
/// dispatcher must fail the test, not hang the suite.
fn run_live(
    mut server: Server,
    reqs: Vec<ChatRequest>,
    timeout: Duration,
) -> (Server, Vec<ChatResponse>) {
    let (done_tx, done_rx) = mpsc::channel();
    let handle = thread::spawn(move || {
        let out = server.run_workload(reqs);
        let _ = done_tx.send(());
        (server, out)
    });
    match done_rx.recv_timeout(timeout) {
        Ok(()) => {
            let (server, out) = handle.join().expect("serve thread panicked");
            (server, out.expect("live serve must not error"))
        }
        Err(_) => panic!("stress serve deadlocked (watchdog fired)"),
    }
}

fn stress_requests(n: usize, agent: Option<&str>) -> Vec<ChatRequest> {
    (0..n as u64)
        .map(|i| {
            let byte = b'a' + (i % 23) as u8;
            let req = ChatRequest::new(i, vec![byte; ISL], OSL);
            match agent {
                Some(a) => req.with_agent(a),
                None => req,
            }
        })
        .collect()
}

/// Open the admission gate wide enough for the whole burst: the stress
/// measures the dispatcher, not the token bucket.
fn open_admission(server: &mut Server) {
    let mut cfg = server.config().clone();
    cfg.admission.rate = 1e9;
    cfg.admission.burst = 1e9;
    cfg.admission.max_queue_depth = N_STRESS * 2;
    cfg.max_new_tokens = OSL;
    cfg.time_scale = 0.0; // modeled host/transfer time costs zero wall-clock
    server.reconfigure(cfg);
}

#[test]
fn ten_thousand_concurrent_dag_requests_zero_drops() {
    let plan = mixed_generation("8b-fp16", "H100", "A100", 1, 2);
    let mut server =
        Server::from_plan_with_engines(Engine::synthetic_pool(plan.pipelines.len()), &plan)
            .unwrap();
    assert_eq!(server.engine_count(), plan.pipelines.len());
    open_admission(&mut server);
    server.install_plan(&plan).unwrap();

    let reqs = stress_requests(N_STRESS, Some(plan.agent.as_str()));
    let (server, responses) = run_live(server, reqs, Duration::from_secs(300));

    // ---- zero drops: one response per request, all successful -------
    assert_eq!(responses.len(), N_STRESS);
    let mut ids = HashSet::with_capacity(N_STRESS);
    for r in &responses {
        assert!(
            r.is_ok(),
            "request {} not ok under load: rejected={} error={:?}",
            r.id,
            r.rejected,
            r.error
        );
        assert!(ids.insert(r.id), "duplicate response for request {}", r.id);
        assert_eq!(
            r.stages.len(),
            plan.bindings.len(),
            "request {}: every binding must run exactly once",
            r.id
        );
    }
    assert_eq!(ids.len(), N_STRESS);

    // ---- bounded memory: the host pool never queues past its slots --
    assert!(
        server.host_high_watermark() <= plan.cpu_workers as u64,
        "host watermark {} exceeded cpu_workers {}",
        server.host_high_watermark(),
        plan.cpu_workers
    );

    // ---- per-group accounting: every request hit every group once ---
    let snap = server.metrics.snapshot();
    for pipe in &plan.pipelines {
        let key = format!("server_group_jobs:{}", pipe.shape_key());
        assert_eq!(
            snap.get(&key).copied().unwrap_or(0.0),
            N_STRESS as f64,
            "group {key} job count off under load"
        );
    }

    // ---- determinism: sampled responses match isolated runs ---------
    let mut solo_server =
        Server::from_plan_with_engines(Engine::synthetic_pool(plan.pipelines.len()), &plan)
            .unwrap();
    open_admission(&mut solo_server);
    solo_server.install_plan(&plan).unwrap();
    let sample: Vec<u64> = (0..16).map(|i| i * (N_STRESS as u64 / 16)).collect();
    let solo_reqs: Vec<ChatRequest> = sample
        .iter()
        .map(|&i| {
            let byte = b'a' + (i % 23) as u8;
            ChatRequest::new(i, vec![byte; ISL], OSL).with_agent(plan.agent.as_str())
        })
        .collect();
    let (_solo, solo_out) = run_live(solo_server, solo_reqs, Duration::from_secs(60));
    for s in &solo_out {
        let under_load = responses.iter().find(|r| r.id == s.id).unwrap();
        assert_eq!(
            under_load.output, s.output,
            "request {}: output under 10k-way load diverged from the \
             isolated run — batching/threading leaked into tokens",
            s.id
        );
        assert_eq!(under_load.tokens, s.tokens);
        assert!(
            (under_load.kv_hop_bytes - s.kv_hop_bytes).abs() < 1.0,
            "request {}: KV hop bytes changed under load",
            s.id
        );
    }
}

#[test]
fn ten_thousand_flat_requests_zero_drops() {
    // No plan installed: the flat prompt→generate path through the
    // continuous batcher and a single engine worker thread.
    let mut server = Server::new(Engine::synthetic_default(), Default::default());
    open_admission(&mut server);

    let reqs = stress_requests(N_STRESS, None);
    let (_server, responses) = run_live(server, reqs, Duration::from_secs(300));

    assert_eq!(responses.len(), N_STRESS);
    let mut ids = HashSet::with_capacity(N_STRESS);
    for r in &responses {
        assert!(r.is_ok(), "flat request {} failed: {:?}", r.id, r.error);
        assert!(ids.insert(r.id), "duplicate flat response {}", r.id);
        assert_eq!(r.tokens, OSL, "flat request {} token count", r.id);
    }

    // Determinism: lanes are independent in the synthetic engine, so a
    // request's bytes must match a fresh single-request run.
    let engine = Engine::synthetic_default();
    for &probe in &[0u64, 4_999, 9_999] {
        let byte = b'a' + (probe % 23) as u8;
        let expect = engine
            .generate_greedy(&[vec![byte; ISL]], OSL)
            .unwrap()
            .remove(0);
        let got = responses.iter().find(|r| r.id == probe).unwrap();
        assert_eq!(
            got.output, expect,
            "flat request {probe} diverged from solo generate"
        );
    }
}
