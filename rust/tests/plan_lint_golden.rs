//! Golden test for `plan lint`: a crafted plan trips every one of the
//! analyzer's five pass categories (topology, bindings, capacity,
//! fabric, SLA) and the rendered diagnostics table plus the report
//! JSON are pinned byte-for-byte. Any change to codes, messages,
//! ordering, or formatting shows up here as an exact-diff failure.

use agentic_hetero::plan::{
    presets, verify, AdmissionPolicy, BatchPolicy, DiagReport, ExecutionPlan, FabricSpec,
    NodeBinding, PipelineBinding, Role, SlaSpec, Stage,
};

/// One deliberate defect per pass category:
///
/// * topology — `io.output` depends on the nonexistent binding 9;
/// * bindings — the decode node's `prefix_overlap` is 1.5;
/// * capacity — 70B fp16 weights (140 GB) on a tp1 Gaudi3 (128 GB);
/// * fabric   — the prefill→decode KV handoff must cross chassis but
///   `scaleout_gbit` is 0;
/// * sla      — a 100 ms end-to-end target under a 541 ms critical
///   path.
fn bad_plan() -> ExecutionPlan {
    let cpu = |op: &str, deps: Vec<usize>| NodeBinding {
        op: op.into(),
        class: "CPU".into(),
        stage: Stage::Cpu,
        latency_s: 0.0005,
        cost_usd: 0.0,
        deps,
        xfer_bytes: 0.0,
        token_fraction: 1.0,
        prefix_overlap: 0.0,
    };
    ExecutionPlan {
        agent: "lint_golden".into(),
        model: "70b-fp16".into(),
        sla: SlaSpec::EndToEnd(0.1),
        bindings: vec![
            cpu("io.input", vec![]),
            NodeBinding {
                op: "llm.prefill".into(),
                class: "H100".into(),
                stage: Stage::LlmPrefill,
                latency_s: 0.04,
                cost_usd: 1e-5,
                deps: vec![0],
                xfer_bytes: 1e6,
                token_fraction: 1.0,
                prefix_overlap: 0.0,
            },
            NodeBinding {
                op: "llm.decode".into(),
                class: "Gaudi3".into(),
                stage: Stage::LlmDecode,
                latency_s: 0.5,
                cost_usd: 1e-5,
                deps: vec![1],
                xfer_bytes: 1e8,
                token_fraction: 1.0,
                prefix_overlap: 1.5,
            },
            cpu("io.output", vec![2, 9]),
        ],
        pipelines: vec![
            PipelineBinding {
                role: Role::Prefill,
                device: "H100".into(),
                tp: 2,
                pp: 1,
                max_batch: 8,
                replicas: 1,
                chassis: 0,
            },
            PipelineBinding {
                role: Role::Decode,
                device: "Gaudi3".into(),
                tp: 1,
                pp: 1,
                max_batch: 16,
                replicas: 2,
                chassis: 1,
            },
        ],
        batching: BatchPolicy::default(),
        admission: AdmissionPolicy::default(),
        fabric: FabricSpec {
            scaleout_gbit: 0.0,
            ..FabricSpec::default()
        },
        cpu_workers: 32,
        cost_usd: 4e-5,
        latency_s: 0.55,
        pass_log: vec![],
    }
}

const EXPECTED_TABLE: &str = "\
plan diagnostics: 4 error(s), 1 warning(s)
  AH001 error binding[3] io.output: dep 9 out of range (plan has 4 bindings)
        fix: point the dep at an existing earlier binding
  AH011 error binding[2] llm.decode: prefix_overlap 1.5 outside [0, 1]
        fix: clamp prefix_overlap to the expected resident-prefix fraction
  AH020 error pipeline[1] decode Gaudi3 tp1 pp1 b16: HBM footprint 145.4 GB (weights 140.0 + KV 5.4 at ctx 1024 x batch 16) exceeds Gaudi3 HBM 128 GB
        fix: raise tp/pp, shrink max_batch, or move the group to a larger-memory device
  AH030 error binding[2] llm.decode: prefill->decode KV handoff from binding 1 must cross chassis but the fabric has no scale-out link (scaleout_gbit = 0)
        fix: give the fabric scale-out bandwidth or co-locate the prefill and decode groups on shared chassis
  AH040 warn  plan: critical-path lower bound 0.541s (prefill 0.040s, decode 0.500s, tool_io 0.001s) exceeds the SLA target 0.100s
        fix: relax the SLA or rebind the critical path onto faster classes
verdict: FAIL
";

const EXPECTED_JSON: &str = r#"{
  "errors": 4,
  "warnings": 1,
  "diags": [
    {
      "code": "AH001",
      "severity": "error",
      "loc": "binding[3] io.output",
      "message": "dep 9 out of range (plan has 4 bindings)",
      "suggestion": "point the dep at an existing earlier binding"
    },
    {
      "code": "AH011",
      "severity": "error",
      "loc": "binding[2] llm.decode",
      "message": "prefix_overlap 1.5 outside [0, 1]",
      "suggestion": "clamp prefix_overlap to the expected resident-prefix fraction"
    },
    {
      "code": "AH020",
      "severity": "error",
      "loc": "pipeline[1] decode Gaudi3 tp1 pp1 b16",
      "message": "HBM footprint 145.4 GB (weights 140.0 + KV 5.4 at ctx 1024 x batch 16) exceeds Gaudi3 HBM 128 GB",
      "suggestion": "raise tp/pp, shrink max_batch, or move the group to a larger-memory device"
    },
    {
      "code": "AH030",
      "severity": "error",
      "loc": "binding[2] llm.decode",
      "message": "prefill->decode KV handoff from binding 1 must cross chassis but the fabric has no scale-out link (scaleout_gbit = 0)",
      "suggestion": "give the fabric scale-out bandwidth or co-locate the prefill and decode groups on shared chassis"
    },
    {
      "code": "AH040",
      "severity": "warn",
      "loc": "plan",
      "message": "critical-path lower bound 0.541s (prefill 0.040s, decode 0.500s, tool_io 0.001s) exceeds the SLA target 0.100s",
      "suggestion": "relax the SLA or rebind the critical path onto faster classes"
    }
  ],
  "passes": [
    {
      "pass": "topology",
      "findings": 1
    },
    {
      "pass": "bindings",
      "findings": 1
    },
    {
      "pass": "capacity",
      "findings": 1
    },
    {
      "pass": "fabric",
      "findings": 1
    },
    {
      "pass": "sla",
      "findings": 1
    }
  ]
}"#;

#[test]
fn lint_table_is_byte_stable_across_all_five_categories() {
    let report = verify::verify(&bad_plan());
    assert_eq!(report.table(), EXPECTED_TABLE);
    assert_eq!(report.errors().count(), 4);
    assert_eq!(report.warnings().count(), 1);
    let counts: Vec<usize> = report.passes.iter().map(|(_, n)| *n).collect();
    assert_eq!(counts, vec![1, 1, 1, 1, 1], "one finding per pass category");
}

#[test]
fn lint_json_is_byte_stable_and_round_trips() {
    let report = verify::verify(&bad_plan());
    let rendered = report.to_json().pretty();
    assert_eq!(rendered, EXPECTED_JSON);
    let back = DiagReport::from_json(&agentic_hetero::util::json::Json::parse(&rendered).unwrap())
        .unwrap();
    assert_eq!(back, report, "report JSON round-trip must be identity");
}

#[test]
fn loader_gate_carries_the_table() {
    let err = verify::ensure_loadable(&bad_plan()).unwrap_err();
    let msg = err.to_string();
    assert!(
        msg.contains("plan rejected by static analysis:"),
        "gate must name the analyzer: {msg}"
    );
    assert!(msg.contains(EXPECTED_TABLE.trim_end()), "gate must attach the table: {msg}");
}

#[test]
fn clean_preset_table_is_a_bare_pass() {
    let report = verify::verify(&presets::homogeneous("8b-fp16", "H100", 2));
    assert_eq!(
        report.table(),
        "plan diagnostics: 0 error(s), 0 warning(s)\nverdict: PASS\n"
    );
}
