//! Failure injection: every subsystem must fail *closed* with a typed
//! error (never panic, never corrupt state) under capacity exhaustion,
//! malformed artifacts, infeasible constraints, and hostile inputs.

use agentic_hetero::cost::hardware::by_name;
use agentic_hetero::cost::model_profile::llama3_70b;
use agentic_hetero::cost::roofline::Parallelism;
use agentic_hetero::cost::Precision;
use agentic_hetero::kvcache::manager::{CacheManager, NodeBudget};
use agentic_hetero::kvcache::paged::PagedAllocator;
use agentic_hetero::opt::parallelism::{best_config, ExploreOpts, SeqShape, SlaMode};
use agentic_hetero::router::router::{Router, RouterConfig, WorkerState};
use agentic_hetero::runtime::Manifest;
use agentic_hetero::Error;

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("ah-fail-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn manifest_rejects_corruption_variants() {
    let write = |dir: &std::path::Path, body: &str| {
        std::fs::write(dir.join("manifest.txt"), body).unwrap();
    };
    let base = "format=1\nvocab=256\nd_model=96\nn_layers=3\nn_heads=4\n\
                n_kv_heads=2\nhead_dim=24\nd_ff=256\nmax_seq=96\nprefill_seq=64\n\
                buckets=1\nnum_params=1\nkv_cache_bytes_b1=1\n";

    // Missing key.
    let d = tmpdir("nokey");
    write(&d, &base.replace("vocab=256\n", ""));
    assert!(matches!(Manifest::load(&d), Err(Error::Runtime(_))));

    // Non-numeric value.
    let d = tmpdir("nan");
    write(&d, &base.replace("vocab=256", "vocab=lots"));
    assert!(Manifest::load(&d).is_err());

    // prefill_seq > max_seq.
    let d = tmpdir("seq");
    write(&d, &base.replace("prefill_seq=64", "prefill_seq=200"));
    let err = Manifest::load(&d).unwrap_err().to_string();
    assert!(err.contains("exceeds max_seq"), "{err}");

    // Unsorted buckets.
    let d = tmpdir("buckets");
    write(&d, &base.replace("buckets=1", "buckets=4,1"));
    for b in ["prefill_b4", "decode_b4", "prefill_b1", "decode_b1"] {
        std::fs::write(d.join(format!("{b}.hlo.txt")), "HloModule x").unwrap();
    }
    assert!(Manifest::load(&d).is_err());

    // Empty bucket list.
    let d = tmpdir("nobuckets");
    write(&d, &base.replace("buckets=1", "buckets="));
    assert!(Manifest::load(&d).is_err());
}

#[test]
fn paged_allocator_survives_exhaustion_storm() {
    // Fill to capacity, keep hammering; allocator must stay consistent
    // and recover fully after frees.
    let mut a = PagedAllocator::new(32, 8);
    let mut live = Vec::new();
    for s in 0..1000u64 {
        match a.alloc_seq(s, 64) {
            Ok(()) => live.push(s),
            Err(Error::Capacity(_)) => break,
            Err(e) => panic!("wrong error type: {e}"),
        }
    }
    assert_eq!(live.len(), 4); // 32 pages / 8 pages-per-seq
    // Appends on full pool fail with Capacity, state intact.
    for _ in 0..100 {
        for &s in &live {
            match a.append_token(s) {
                Ok(()) | Err(Error::Capacity(_)) => {}
                Err(e) => panic!("wrong error: {e}"),
            }
        }
        assert_eq!(a.free_pages() + a.used_pages(), 32);
    }
    for s in live {
        a.free_seq(s).unwrap();
    }
    assert_eq!(a.free_pages(), 32);
    assert_eq!(a.fragmentation(), 0.0);
}

#[test]
fn cache_manager_single_oversized_entry_fails_closed() {
    let mut m = CacheManager::new(vec![NodeBudget {
        hbm: 100.0,
        dram: 100.0,
        disk: 100.0,
    }]);
    // Entry bigger than HBM: rejected up front, nothing changed.
    assert!(matches!(
        m.insert(1, 0, 150.0, 0),
        Err(Error::Capacity(_))
    ));
    assert!(m.is_empty());
    // Fill the ladder until even Object would be needed: inserts still
    // succeed because Object is unbounded, and every entry is findable.
    for s in 0..30 {
        m.insert(s, 0, 90.0, s).unwrap();
    }
    for s in 0..30 {
        assert!(m.locate(s).is_some(), "entry {s} lost during offload");
    }
}

#[test]
fn router_with_all_workers_draining_errors() {
    let mut r = Router::new(RouterConfig::default());
    for id in 0..4 {
        r.upsert_worker(WorkerState {
            id,
            models: vec!["tiny".into()],
            outstanding: 0,
            draining: true,
        });
    }
    let cache = CacheManager::new(vec![NodeBudget {
        hbm: 1e9,
        dram: 1e9,
        disk: 1e9,
    }]);
    match r.route("tiny", None, None, &cache) {
        Err(Error::Capacity(msg)) => assert!(msg.contains("tiny")),
        other => panic!("expected capacity error, got {other:?}"),
    }
    // Un-drain one: routing recovers instantly.
    r.set_draining(2, false);
    assert_eq!(r.route("tiny", None, None, &cache).unwrap().0, 2);
}

#[test]
fn explorer_returns_none_not_panic_for_impossible_configs() {
    // 70B FP16 on a single A40 scale-up domain with a 1ms TBT target:
    // nothing fits; the explorer must return None.
    let m = llama3_70b(Precision::Fp16);
    let a40 = by_name("A40").unwrap();
    let mut opts = ExploreOpts::default();
    opts.pp_candidates = vec![1];
    opts.tp_candidates = vec![1, 2];
    let cfg = best_config(
        &m,
        &a40,
        &a40,
        SeqShape::fig8(),
        SlaMode::Latency {
            ttft_s: 0.001,
            tbt_s: 0.001,
        },
        &opts,
    );
    assert!(cfg.is_none());
}

#[test]
fn simulator_rejects_stalling_placements() {
    use agentic_hetero::cluster::sim::{ClusterSim, Placement, PipelineSpec};
    use agentic_hetero::cluster::trace::{generate, TraceConfig};
    use agentic_hetero::transport::fabric::Fabric;

    // Decode max_batch = 0 can never drain: the simulator must detect
    // the stall (all events consumed, requests incomplete) and error.
    let h100 = by_name("H100").unwrap();
    let placement = Placement {
        prefill: vec![PipelineSpec {
            device: h100.clone(),
            par: Parallelism { tp: 1, pp: 1 },
            max_batch: 4,
            chassis: 0,
        }],
        decode: vec![PipelineSpec {
            device: h100.clone(),
            par: Parallelism { tp: 1, pp: 1 },
            max_batch: 0,
            chassis: 1,
        }],
    };
    let mut sim = ClusterSim::new(
        agentic_hetero::cost::model_profile::llama3_8b(Precision::Fp16),
        placement,
        Fabric::new(2, 8, 900.0, 400.0),
    );
    let trace = generate(&TraceConfig {
        n_requests: 4,
        rate: 10.0,
        isl_mean: 128,
        osl_mean: 8,
        sigma: 0.0,
        seed: 1,
    });
    let err = sim.run(&trace).unwrap_err().to_string();
    assert!(err.contains("stalled"), "{err}");
}

#[test]
fn fabric_rejects_out_of_range_addresses() {
    use agentic_hetero::transport::fabric::{Fabric, NodeAddr};
    let mut f = Fabric::new(2, 8, 900.0, 400.0);
    let good = NodeAddr { chassis: 0, slot: 0 };
    for bad in [
        NodeAddr { chassis: 2, slot: 0 },
        NodeAddr { chassis: 0, slot: 8 },
    ] {
        assert!(f.transfer(good, bad, 1.0, 0.0).is_err());
        assert!(f.transfer(bad, good, 1.0, 0.0).is_err());
    }
}

/// A failing tool node in the *live* DAG path fails only its own
/// request: every other request completes, the dispatcher never wedges,
/// and the server keeps serving subsequent workloads.
#[test]
#[cfg(not(feature = "pjrt"))]
fn live_tool_stage_failure_isolates_request() {
    use agentic_hetero::plan::{
        AdmissionPolicy, BatchPolicy, ExecutionPlan, FabricSpec, NodeBinding, SlaSpec,
        Stage,
    };
    use agentic_hetero::runtime::Engine;
    use agentic_hetero::server::{ChatRequest, Server};

    let cpu = |op: &str, latency_s: f64, deps: Vec<usize>| NodeBinding {
        op: op.into(),
        class: "CPU".into(),
        stage: Stage::Cpu,
        latency_s,
        cost_usd: 0.0,
        deps,
        xfer_bytes: 0.0,
        token_fraction: 1.0,
        prefix_overlap: 0.0,
    };
    let plan = ExecutionPlan {
        agent: "flaky_agent".into(),
        model: String::new(),
        sla: SlaSpec::None,
        bindings: vec![
            cpu("io.input", 0.0002, vec![]),
            cpu("tool.flaky", 0.001, vec![0]),
            cpu("io.output", 0.0002, vec![1]),
        ],
        pipelines: vec![],
        batching: BatchPolicy::default(),
        admission: AdmissionPolicy::default(),
        fabric: FabricSpec::default(),
        cpu_workers: 2,
        cost_usd: 0.0,
        latency_s: 0.002,
        pass_log: vec![],
    };
    let mut server = Server::from_plan(Engine::synthetic_default(), &plan).unwrap();
    // Request 3's tool call fails; everyone else is fine.
    server.inject_host_fault(|op, req| op == "tool.flaky" && req == 3);

    let reqs: Vec<ChatRequest> = (0..8u64)
        .map(|i| ChatRequest::new(i, "x", 4).with_agent("flaky_agent"))
        .collect();
    let responses = server.run_workload(reqs).unwrap();
    assert_eq!(responses.len(), 8, "every request must get a response");
    for r in &responses {
        if r.id == 3 {
            assert!(r.failed, "request 3 must fail");
            assert!(!r.rejected);
            assert!(
                r.error.as_deref().unwrap().contains("tool.flaky"),
                "{:?}",
                r.error
            );
        } else {
            assert!(r.is_ok(), "request {} must survive: {:?}", r.id, r.error);
            assert_eq!(r.stages.len(), 3);
        }
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap["server_stage_failures"], 1.0);

    // The dispatcher is not wedged: a second workload still serves.
    let reqs: Vec<ChatRequest> = (10..14u64)
        .map(|i| ChatRequest::new(i, "y", 4).with_agent("flaky_agent"))
        .collect();
    let responses = server.run_workload(reqs).unwrap();
    assert_eq!(responses.len(), 4);
    assert!(responses.iter().all(|r| r.is_ok()));
}

/// A fault on an upstream IO stage must prevent downstream stages of
/// that request from running at all (fail fast, no orphan work), while
/// the LLM path of other requests keeps flowing.
#[test]
#[cfg(not(feature = "pjrt"))]
fn live_io_failure_skips_downstream_stages() {
    use agentic_hetero::runtime::Engine;
    use agentic_hetero::server::{ChatRequest, Server};

    // tiny_plan shape from public types: cpu → prefill → decode → cpu.
    let plan = {
        use agentic_hetero::plan::{
            AdmissionPolicy, BatchPolicy, ExecutionPlan, FabricSpec, NodeBinding,
            PipelineBinding, Role, SlaSpec, Stage,
        };
        ExecutionPlan {
            agent: "io_agent".into(),
            model: "8b-fp16".into(),
            sla: SlaSpec::None,
            bindings: vec![
                NodeBinding {
                    op: "io.input".into(),
                    class: "CPU".into(),
                    stage: Stage::Cpu,
                    latency_s: 0.0002,
                    cost_usd: 0.0,
                    deps: vec![],
                    xfer_bytes: 0.0,
                    token_fraction: 1.0,
                    prefix_overlap: 0.0,
                },
                NodeBinding {
                    op: "llm.prefill".into(),
                    class: "H100".into(),
                    stage: Stage::LlmPrefill,
                    latency_s: 0.03,
                    cost_usd: 1e-5,
                    deps: vec![0],
                    xfer_bytes: 1e6,
                    token_fraction: 1.0,
                    prefix_overlap: 0.0,
                },
                NodeBinding {
                    op: "llm.decode".into(),
                    class: "H100".into(),
                    stage: Stage::LlmDecode,
                    latency_s: 0.3,
                    cost_usd: 2e-5,
                    deps: vec![1],
                    xfer_bytes: 1e7,
                    token_fraction: 1.0,
                    prefix_overlap: 0.0,
                },
                NodeBinding {
                    op: "io.output".into(),
                    class: "CPU".into(),
                    stage: Stage::Cpu,
                    latency_s: 0.0002,
                    cost_usd: 0.0,
                    deps: vec![2],
                    xfer_bytes: 0.0,
                    token_fraction: 1.0,
                    prefix_overlap: 0.0,
                },
            ],
            pipelines: vec![
                PipelineBinding {
                    role: Role::Prefill,
                    device: "H100".into(),
                    tp: 1,
                    pp: 1,
                    max_batch: 8,
                    replicas: 1,
                    chassis: 0,
                },
                PipelineBinding {
                    role: Role::Decode,
                    device: "H100".into(),
                    tp: 1,
                    pp: 1,
                    max_batch: 8,
                    replicas: 1,
                    chassis: 1,
                },
            ],
            batching: BatchPolicy::default(),
            admission: AdmissionPolicy::default(),
            fabric: FabricSpec::default(),
            cpu_workers: 2,
            cost_usd: 3e-5,
            latency_s: 0.33,
            pass_log: vec![],
        }
    };
    let mut server = Server::from_plan(Engine::synthetic_default(), &plan).unwrap();
    server.inject_host_fault(|op, req| op == "io.input" && req == 0);

    let reqs: Vec<ChatRequest> = (0..4u64)
        .map(|i| ChatRequest::new(i, "hello engine ", 6).with_agent("io_agent"))
        .collect();
    let responses = server.run_workload(reqs).unwrap();
    assert_eq!(responses.len(), 4);
    assert!(responses[0].failed);
    assert_eq!(responses[0].tokens, 0, "no LLM work for the failed request");
    for r in &responses[1..] {
        assert!(r.is_ok());
        assert_eq!(r.tokens, 6);
        assert_eq!(r.stages.len(), 4);
    }
    // The failed request never reached the engine: 3 prefill jobs only.
    let snap = server.metrics.snapshot();
    assert_eq!(snap["server_prefill_jobs"], 3.0);
    assert_eq!(snap["server_decode_jobs"], 3.0);
}

#[test]
fn config_parser_hostile_inputs() {
    use agentic_hetero::config::{parse, DeployConfig};
    for src in [
        "key",
        "[unclosed",
        "[[x]\n",
        "k = [1, 2",
        "k = \"unterminated",
        "k = 1e999x",
    ] {
        assert!(parse(src).is_err(), "should reject {src:?}");
    }
    // Unknown sections/keys are ignored, not fatal (forward compat).
    let cfg = DeployConfig::from_str_src("[future_section]\nwhatever = 3\n").unwrap();
    assert_eq!(cfg.max_batch, 4);
}
