//! Property tests: the exact branch-and-bound solver and the relaxed
//! MILP formulation agree on randomized small task graphs.
//!
//! `solve_relaxed` approximates pair-dependent edge transfers by their
//! per-pair minimum (a valid lower bound, exact when transfers are
//! assignment-independent), so the contract is:
//!
//! * **edge-free / constant-edge chains** — identical objective values;
//! * **pair-dependent edges** — the exact solver is optimal, so its
//!   true cost never exceeds the relaxed solver's realized cost;
//! * the heuristic never beats the exact optimum either.

use agentic_hetero::opt::assignment::{
    AssignmentProblem, EdgeSpec, HardwareClass, Sla, TaskSpec,
};
use agentic_hetero::util::prop::{check_cases, vec_of};
use agentic_hetero::util::rng::Rng;

/// Random chain problem: 2–5 tasks × 2–3 classes, no forbidden sets.
fn random_chain(rng: &mut Rng, with_edges: bool) -> AssignmentProblem {
    let n = rng.index(4) + 2;
    let h = rng.index(2) + 2;
    let tasks: Vec<TaskSpec> = (0..n)
        .map(|i| TaskSpec {
            name: format!("t{i}"),
            latency_s: (0..h).map(|_| 0.01 + rng.f64() * 0.1).collect(),
            cost_usd: (0..h).map(|_| 0.05 + rng.f64()).collect(),
            capacity_use: 0.0,
            forbidden: vec![],
        })
        .collect();
    let edges: Vec<EdgeSpec> = (1..n)
        .map(|i| {
            if with_edges {
                // Pair-dependent transfer: zero on the diagonal (stay on
                // the same class), a random penalty off-diagonal — the
                // worked example's d_ij structure.
                let penalty_c = rng.f64() * 0.2;
                let penalty_t = rng.f64() * 0.02;
                let mut lat = vec![vec![0.0; h]; h];
                let mut cost = vec![vec![0.0; h]; h];
                for (a, row) in lat.iter_mut().enumerate() {
                    for (b, v) in row.iter_mut().enumerate() {
                        if a != b {
                            *v = penalty_t;
                        }
                    }
                }
                for (a, row) in cost.iter_mut().enumerate() {
                    for (b, v) in row.iter_mut().enumerate() {
                        if a != b {
                            *v = penalty_c;
                        }
                    }
                }
                EdgeSpec {
                    from: i - 1,
                    to: i,
                    latency_s: lat,
                    cost_usd: cost,
                }
            } else {
                EdgeSpec::free(i - 1, i, h)
            }
        })
        .collect();
    let classes = (0..h)
        .map(|j| HardwareClass {
            name: format!("C{j}"),
            capacity: 0.0,
        })
        .collect();
    AssignmentProblem {
        classes,
        tasks,
        edges,
        sla: Sla::None,
    }
}

#[test]
fn exact_and_relaxed_agree_without_edge_terms() {
    check_cases("exact-vs-relaxed/edge-free", 64, &mut |rng| {
        let p = random_chain(rng, false);
        let e = p.solve_exact().unwrap();
        let r = p.solve_relaxed().unwrap();
        assert!(
            (e.cost_usd - r.cost_usd).abs() < 1e-9,
            "exact {} vs relaxed {} on {:?}",
            e.cost_usd,
            r.cost_usd,
            p.tasks.iter().map(|t| &t.cost_usd).collect::<Vec<_>>()
        );
        assert_eq!(e.choice, r.choice);
    });
}

#[test]
fn exact_lower_bounds_relaxed_with_pair_dependent_edges() {
    check_cases("exact-vs-relaxed/pair-dependent", 64, &mut |rng| {
        let p = random_chain(rng, true);
        let e = p.solve_exact().unwrap();
        let r = p.solve_relaxed().unwrap();
        // Exact is optimal over the true (edge-aware) objective; the
        // relaxed solver's realized cost can only match or exceed it.
        assert!(
            e.cost_usd <= r.cost_usd + 1e-9,
            "exact {} beats relaxed {}",
            e.cost_usd,
            r.cost_usd
        );
        // Both report the true evaluated cost of their choice.
        let (re_cost, _) = p.evaluate(&r.choice);
        assert!((re_cost - r.cost_usd).abs() < 1e-9);
        let (ee_cost, _) = p.evaluate(&e.choice);
        assert!((ee_cost - e.cost_usd).abs() < 1e-9);
    });
}

#[test]
fn heuristic_never_beats_exact() {
    check_cases("heuristic-vs-exact", 64, &mut |rng| {
        let p = random_chain(rng, rng.bool(0.5));
        let e = p.solve_exact().unwrap();
        let h = p.solve_heuristic().unwrap();
        assert!(
            h.cost_usd >= e.cost_usd - 1e-9,
            "heuristic {} beats exact {}",
            h.cost_usd,
            e.cost_usd
        );
    });
}

#[test]
fn agreement_respects_forbidden_classes() {
    check_cases("exact-vs-relaxed/forbidden", 48, &mut |rng| {
        let mut p = random_chain(rng, false);
        let h = p.classes.len();
        // Forbid one random class on one random task (keep ≥1 allowed).
        let ti = rng.index(p.tasks.len());
        let cj = rng.index(h);
        p.tasks[ti].forbidden = vec![cj];
        let e = p.solve_exact().unwrap();
        let r = p.solve_relaxed().unwrap();
        assert_ne!(e.choice[ti], cj);
        assert_ne!(r.choice[ti], cj);
        assert!((e.cost_usd - r.cost_usd).abs() < 1e-9);
    });
}

#[test]
fn vec_of_generator_available_for_future_shapes() {
    // Exercise the prop harness's vector generator on task sizes so the
    // helper stays covered (and documents how to extend these tests to
    // DAG-shaped problems).
    let mut rng = Rng::new(7);
    let sizes = vec_of(&mut rng, 6, |r| r.index(4) + 2);
    assert!(sizes.len() <= 6);
    assert!(sizes.iter().all(|s| (2..=5).contains(s)));
}
