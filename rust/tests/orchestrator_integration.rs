//! End-to-end orchestration: a bursty trace drives the closed loop
//! (observe → decide → re-plan → diff → migrate → apply) through the
//! DAG simulator. The loop must emit ≥ 2 distinct plans connected by
//! valid migrations, the simulator must execute through the fleet
//! changes without dropping in-flight requests, and the timeline must
//! round-trip losslessly through `util::json`.

use agentic_hetero::cluster::trace::{generate, Request, TraceConfig};
use agentic_hetero::orchestrator::{
    capacity_trajectory, converges, shape_map_of, Executor, Orchestrator, OrchestratorConfig,
    SimExecutor, Timeline, TimelineEvent,
};
use agentic_hetero::plan::{
    AdmissionPolicy, BatchPolicy, ExecutionPlan, FabricSpec, NodeBinding, PipelineBinding,
    Role, SlaSpec, Stage,
};
use agentic_hetero::planner::autoscale::AutoscalerConfig;
use agentic_hetero::planner::migration::MigrationPlan;

/// A deliberately undersized fleet: one H100 prefill pipeline and one
/// Gaudi3 decode pipeline (batch 8), so a burst saturates decode fast.
fn small_plan() -> ExecutionPlan {
    ExecutionPlan {
        agent: "burst_agent".into(),
        model: "8b-fp16".into(),
        sla: SlaSpec::EndToEnd(5.0),
        bindings: vec![
            NodeBinding {
                op: "io.input".into(),
                class: "CPU".into(),
                stage: Stage::Cpu,
                latency_s: 0.0005,
                cost_usd: 0.0,
                deps: vec![],
                xfer_bytes: 0.0,
                token_fraction: 1.0,
                prefix_overlap: 0.0,
            },
            NodeBinding {
                op: "llm.prefill".into(),
                class: "H100".into(),
                stage: Stage::LlmPrefill,
                latency_s: 0.05,
                cost_usd: 1e-5,
                deps: vec![0],
                xfer_bytes: 1e6,
                token_fraction: 1.0,
                prefix_overlap: 0.0,
            },
            NodeBinding {
                op: "llm.decode".into(),
                class: "Gaudi3".into(),
                stage: Stage::LlmDecode,
                latency_s: 0.5,
                cost_usd: 2e-5,
                deps: vec![1],
                xfer_bytes: 1e8,
                token_fraction: 1.0,
                prefix_overlap: 0.0,
            },
            NodeBinding {
                op: "io.output".into(),
                class: "CPU".into(),
                stage: Stage::Cpu,
                latency_s: 0.0005,
                cost_usd: 0.0,
                deps: vec![2],
                xfer_bytes: 0.0,
                token_fraction: 1.0,
                prefix_overlap: 0.0,
            },
        ],
        pipelines: vec![
            PipelineBinding {
                role: Role::Prefill,
                device: "H100".into(),
                tp: 1,
                pp: 1,
                max_batch: 8,
                replicas: 1,
                chassis: 0,
            },
            PipelineBinding {
                role: Role::Decode,
                device: "Gaudi3".into(),
                tp: 1,
                pp: 1,
                max_batch: 8,
                replicas: 1,
                chassis: 1,
            },
        ],
        batching: BatchPolicy::default(),
        admission: AdmissionPolicy::default(),
        fabric: FabricSpec::default(),
        cpu_workers: 64,
        cost_usd: 3e-5,
        latency_s: 0.55,
        pass_log: vec![],
    }
}

/// Burst then lull: 120 requests at 30 req/s (~4 s of heavy load),
/// then 40 at 0.25 req/s (a ~160 s quiet tail) — enough hot windows to
/// scale up and enough idle ones to scale back down deterministically.
fn burst_then_lull() -> Vec<Request> {
    let burst = generate(&TraceConfig {
        n_requests: 120,
        rate: 30.0,
        isl_mean: 256,
        osl_mean: 64,
        sigma: 0.0,
        seed: 7,
    });
    let t0 = burst.last().unwrap().arrive_s;
    let mut lull = generate(&TraceConfig {
        n_requests: 40,
        rate: 0.25,
        isl_mean: 256,
        osl_mean: 64,
        sigma: 0.0,
        seed: 8,
    });
    for (i, r) in lull.iter_mut().enumerate() {
        r.arrive_s += t0;
        r.id = 120 + i as u64;
    }
    let mut all = burst;
    all.extend(lull);
    all
}

fn orchestrator() -> Orchestrator {
    let cfg = OrchestratorConfig {
        window_s: 2.0,
        autoscale: AutoscalerConfig {
            high_watermark: 0.80,
            low_watermark: 0.25,
            patience: 2,
            min_pipelines: 1,
            max_pipelines: 16,
        },
        backlog_factor: 1.0,
        cpu_autoscale: None,
    };
    Orchestrator::new(cfg, small_plan(), "burst_then_lull", "sim").unwrap()
}

#[test]
fn bursty_trace_scales_up_then_down_and_timeline_round_trips() {
    let trace = burst_then_lull();
    let mut exec = SimExecutor::new(&trace);
    let timeline = exec.orchestrate(orchestrator()).unwrap();
    let report = exec.report.as_ref().expect("sim must finish");

    // --- the simulator executed through every fleet change ----------
    assert_eq!(report.n_requests, 160, "no in-flight request dropped");
    assert_eq!(
        report.output_tokens,
        trace.iter().map(|r| r.osl).sum::<u64>()
    );

    // --- ≥ 2 distinct plans connected by valid migrations ------------
    let plans = timeline.plans();
    assert!(
        plans.len() >= 2,
        "burst must force a re-plan: {}",
        timeline.summary()
    );
    assert!(
        plans.windows(2).any(|w| w[0] != w[1]),
        "emitted plans must be distinct"
    );
    for p in &plans {
        p.validate().unwrap();
    }
    // Both directions fired: the burst scaled decode up, the lull back down.
    let decode_totals: Vec<u32> = plans
        .iter()
        .map(|p| {
            p.pipelines
                .iter()
                .filter(|pl| pl.role == Role::Decode)
                .map(|pl| pl.replicas)
                .sum()
        })
        .collect();
    assert!(
        decode_totals.windows(2).any(|w| w[1] > w[0]),
        "scale-up missing: {decode_totals:?}"
    );
    assert!(
        decode_totals.windows(2).any(|w| w[1] < w[0]),
        "scale-down missing: {decode_totals:?}"
    );

    // Every migration in the timeline is capacity-safe and convergent
    // against the plan sequence it connects: migration i moves the
    // fleet from plan i to plan i+1.
    let migs: Vec<&MigrationPlan> = timeline
        .events
        .iter()
        .filter_map(|e| match e {
            TimelineEvent::Migration { plan, .. } => Some(plan),
            _ => None,
        })
        .collect();
    assert!(migs.len() >= 2, "expected ≥2 migrations: {}", timeline.summary());
    assert_eq!(
        plans.len(),
        migs.len() + 1,
        "each re-plan carries exactly one migration"
    );
    for (i, m) in migs.iter().enumerate() {
        let cur = shape_map_of(plans[i]);
        let tgt = shape_map_of(plans[i + 1]);
        capacity_trajectory(&cur, &m.steps).unwrap();
        assert!(converges(&cur, &tgt, &m.steps));
    }

    // --- SLA attainment is recorded and sane -------------------------
    let sla = timeline.sla_attainment();
    assert!((0.0..=1.0).contains(&sla), "sla={sla}");
    assert!(
        timeline
            .events
            .iter()
            .any(|e| matches!(e, TimelineEvent::Window { .. })),
        "windows must be recorded"
    );

    // --- lossless JSON round-trip ------------------------------------
    let text = timeline.to_json_string();
    let back = Timeline::parse_json(&text).unwrap();
    assert_eq!(back, timeline, "timeline must round-trip losslessly");
    assert_eq!(back.to_json_string(), text, "byte-stable serialization");
}

#[test]
fn orchestrated_run_is_deterministic() {
    let trace = burst_then_lull();
    let mut e1 = SimExecutor::new(&trace);
    let t1 = e1.orchestrate(orchestrator()).unwrap();
    let mut e2 = SimExecutor::new(&trace);
    let t2 = e2.orchestrate(orchestrator()).unwrap();
    assert_eq!(t1, t2, "same trace + same policy ⇒ same timeline");
    assert_eq!(
        e1.report.unwrap().events_processed,
        e2.report.unwrap().events_processed
    );
}

#[test]
fn host_heavy_trace_scales_cpu_workers_through_the_loop() {
    // A CPU-bottlenecked plan (slow tool stages, 2 workers): sustained
    // host_util drives the cpu_workers autoscaler, the plan diff types
    // the resize, and the simulator's worker pool grows mid-run.
    let mut plan = small_plan();
    plan.cpu_workers = 2;
    plan.bindings[0].latency_s = 0.05;
    plan.bindings[3].latency_s = 0.05;
    let trace = generate(&TraceConfig {
        n_requests: 120,
        rate: 30.0,
        isl_mean: 64,
        osl_mean: 8,
        sigma: 0.0,
        seed: 13,
    });
    let cfg = OrchestratorConfig {
        window_s: 1.0,
        autoscale: AutoscalerConfig {
            high_watermark: 2.0, // unreachable: pipelines never scale
            low_watermark: -1.0,
            patience: 2,
            min_pipelines: 1,
            max_pipelines: 16,
        },
        backlog_factor: 1.0,
        cpu_autoscale: Some(AutoscalerConfig {
            high_watermark: 0.8,
            low_watermark: -1.0, // never shrink (keeps the test focused)
            patience: 2,
            min_pipelines: 1,
            max_pipelines: 64,
        }),
    };
    let orch = Orchestrator::new(cfg, plan, "host_heavy", "sim").unwrap();
    let mut exec = SimExecutor::new(&trace);
    let timeline = exec.orchestrate(orch).unwrap();
    assert_eq!(exec.report.unwrap().n_requests, 120, "no request dropped");
    let workers: Vec<u32> = timeline.plans().iter().map(|p| p.cpu_workers).collect();
    assert!(
        workers.len() >= 2,
        "host pressure must emit a re-plan: {}",
        timeline.summary()
    );
    assert!(
        workers.windows(2).any(|w| w[1] > w[0]),
        "cpu_workers must grow under host pressure: {workers:?}"
    );
    // The resize is typed in the diff stream.
    assert!(timeline.events.iter().any(|e| matches!(
        e,
        TimelineEvent::Diff { diff, .. }
            if diff.policy.iter().any(|p| p.field == "cpu_workers")
    )));
}

#[test]
fn mixed_generation_fleet_rebalances_across_groups() {
    use agentic_hetero::plan::presets::mixed_generation;

    // The paper's headline scenario: decode split across two hardware
    // generations. A burst then a lull forces scale-up and scale-down;
    // the scored retarget distributes both across the generations and
    // re-aligns the token split — every fleet change on this plan is a
    // cross-group rebalance. Deliberately tiny decode batch slots so
    // the burst's *backlog* (not a device-model-dependent utilization
    // figure) drives the pressure signal deterministically.
    let mut plan = mixed_generation("8b-fp16", "H100", "A100", 1, 1);
    plan.pipelines[1].max_batch = 2;
    plan.pipelines[2].max_batch = 2;
    let trace = burst_then_lull();
    let cfg = OrchestratorConfig {
        window_s: 2.0,
        autoscale: AutoscalerConfig {
            high_watermark: 0.80,
            low_watermark: 0.25,
            patience: 2,
            min_pipelines: 1,
            max_pipelines: 16,
        },
        backlog_factor: 1.0,
        cpu_autoscale: None,
    };
    let orch = Orchestrator::new(cfg, plan.clone(), "burst_then_lull", "sim").unwrap();
    let mut exec = SimExecutor::new(&trace);
    let timeline = exec.orchestrate(orch).unwrap();
    let report = exec.report.as_ref().expect("sim must finish");

    // Nothing dropped across the cross-group fleet changes.
    assert_eq!(report.n_requests, 160);

    // ≥ 1 cross-group rebalance diff in the timeline (the acceptance
    // gate for `orchestrate` on a mixed-generation trace).
    assert!(
        timeline.n_cross_group_rebalances() >= 1,
        "mixed fleet must rebalance across groups: {}",
        timeline.summary()
    );
    // The rebalanced plans keep both generations alive and shift the
    // sibling token split with the capacity.
    for p in timeline.plans() {
        p.validate().unwrap();
        let decode_devs: Vec<&str> = p
            .pipelines
            .iter()
            .filter(|g| g.role == Role::Decode)
            .map(|g| g.device.as_str())
            .collect();
        assert_eq!(decode_devs, vec!["H100", "A100"]);
        let tf_sum = p.bindings[2].token_fraction + p.bindings[3].token_fraction;
        assert!((tf_sum - 1.0).abs() < 1e-6, "split stays a partition: {tf_sum}");
    }
    // At least one emitted plan moved the token split off the initial
    // 50/50 (load followed the hardware).
    assert!(
        timeline.plans().iter().any(|p| {
            (p.bindings[2].token_fraction - 0.5).abs() > 1e-9
        }),
        "token fractions must follow the capacity shift"
    );

    // The record round-trips losslessly with its group-granular events.
    let text = timeline.to_json_string();
    let back = Timeline::parse_json(&text).unwrap();
    assert_eq!(back, timeline);
    assert_eq!(back.to_json_string(), text);

    // Conformance: the static analyzer subsumes the runtime rejection
    // classes — every plan the loop adopted re-verifies free of
    // Error-severity diagnostics, and a run of clean plans never
    // records a typed rejection.
    use agentic_hetero::plan::verify;
    for p in timeline.plans() {
        let report = verify::verify(p);
        assert!(
            !report.has_errors(),
            "adopted plan must verify clean:\n{}",
            report.table()
        );
    }
    assert!(
        !timeline
            .events
            .iter()
            .any(|e| matches!(e, TimelineEvent::Rejection { .. })),
        "statically-clean plans must never trip a runtime rejection: {}",
        timeline.summary()
    );
}

#[test]
fn infeasible_replan_candidate_is_statically_rejected_before_lowering() {
    use agentic_hetero::plan::verify;

    let mut orch = orchestrator();

    // An infeasible re-plan candidate: swapping the model to 70B fp16
    // leaves 140 GB of weights on tp1 groups with 80–128 GB of HBM
    // (AH020). The pre-flight must reject it *before* any migration is
    // lowered, keeping the live plan untouched.
    let mut candidate = small_plan();
    candidate.model = "70b-fp16".into();
    candidate.pipelines[1].replicas = 4;
    assert!(
        verify::verify(&candidate).has_errors(),
        "candidate must be statically infeasible"
    );
    let (change, rejections) = orch.propose_plan(candidate, 1.0, 0.0).unwrap();
    assert!(change.is_none(), "infeasible candidate must not lower a migration");
    assert!(
        rejections.iter().any(|r| r.reason.contains("AH020")),
        "rejection must carry the analyzer code: {rejections:?}"
    );
    assert!(
        verify::verify(orch.current()).is_clean(),
        "live plan must stay untouched"
    );

    // A clean candidate through the same entry point is adopted with a
    // capacity-safe migration.
    let mut good = small_plan();
    good.pipelines[1].replicas = 3;
    let (change, rejections) = orch.propose_plan(good, 2.0, 0.0).unwrap();
    assert!(rejections.is_empty());
    let change = change.expect("clean candidate must be adopted");
    assert!(!change.migration.steps.is_empty());

    // The timeline shows the rejection and exactly the one adopted
    // migration — nothing was lowered for the infeasible candidate.
    let timeline = orch.finish(None);
    assert!(
        timeline.events.iter().any(|e| matches!(
            e,
            TimelineEvent::Rejection { reason, .. } if reason.contains("AH020")
        )),
        "rejection must be recorded: {}",
        timeline.summary()
    );
    assert_eq!(timeline.n_migrations(), 1);
}

#[test]
fn steady_load_never_migrates() {
    // Mid-band utilization: the hysteresis must hold the fleet still.
    let trace = generate(&TraceConfig {
        n_requests: 64,
        rate: 2.0,
        isl_mean: 256,
        osl_mean: 32,
        sigma: 0.0,
        seed: 11,
    });
    let mut plan = small_plan();
    plan.pipelines[1].replicas = 2; // comfortable decode headroom
    let cfg = OrchestratorConfig {
        window_s: 2.0,
        autoscale: AutoscalerConfig {
            high_watermark: 0.95,
            low_watermark: -1.0, // never scale down
            patience: 2,
            min_pipelines: 1,
            max_pipelines: 16,
        },
        backlog_factor: 1.0,
        cpu_autoscale: None,
    };
    let orch = Orchestrator::new(cfg, plan, "steady", "sim").unwrap();
    let mut exec = SimExecutor::new(&trace);
    let timeline = exec.orchestrate(orch).unwrap();
    assert_eq!(timeline.n_plans(), 1, "{}", timeline.summary());
    assert_eq!(timeline.n_migrations(), 0);
    assert_eq!(exec.report.unwrap().n_requests, 64);
}
