//! Integration: the real PJRT runtime + serving loop over the AOT
//! artifact bundle (requires `make artifacts`; tests self-skip when the
//! bundle is absent so `cargo test` stays green pre-build).
//!
//! The whole file is additionally gated on the `pjrt` cargo feature:
//! without it the engine is a stub and there is nothing to integrate.

#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use agentic_hetero::runtime::{Engine, Manifest};
use agentic_hetero::server::{ChatRequest, Server, ServerConfig};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

// The xla PJRT client is !Send (Rc + raw pointers), so each test loads
// its own engine; related assertions are consolidated per load to keep
// the suite fast.
macro_rules! require_engine {
    () => {
        match artifacts_dir() {
            Some(d) => Engine::load(d).unwrap(),
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn manifest_matches_model_config() {
    let dir = require_artifacts!();
    let m = Manifest::load(&dir).unwrap();
    assert_eq!(m.vocab, 256);
    assert_eq!(m.d_model % m.n_heads, 0);
    assert_eq!(m.head_dim, m.d_model / m.n_heads);
    // Eq. 3 cross-check: 2·L·Hkv·D·Smax·BPE.
    let expect = 2 * m.n_layers * m.n_kv_heads * m.head_dim * m.max_seq * 4;
    assert_eq!(m.kv_cache_bytes_b1 as usize, expect);
}

#[test]
fn engine_loads_and_generates_deterministically() {
    let engine = require_engine!();
    assert_eq!(engine.platform(), "cpu");

    let prompts = vec![b"the system ".to_vec()];
    let a = engine.generate_greedy(&prompts, 12).unwrap();
    let b = engine.generate_greedy(&prompts, 12).unwrap();
    assert_eq!(a, b, "greedy generation must be deterministic");
    assert_eq!(a[0].len(), 12);
}

#[test]
fn trained_model_emits_plausible_bytes() {
    // The build-time training corpus is this repo's documentation, so a
    // common-English prompt must yield mostly printable ASCII.
    let engine = require_engine!();
    let out = engine
        .generate_greedy(&[b"the paper describes the ".to_vec()], 24)
        .unwrap();
    let printable = out[0]
        .iter()
        .filter(|b| (0x20..0x7F).contains(*b) || **b == b'\n')
        .count();
    assert!(
        printable * 10 >= out[0].len() * 8,
        "output not mostly printable: {:?}",
        String::from_utf8_lossy(&out[0])
    );
}

#[test]
fn prefill_batch_lanes_are_independent() {
    let engine = require_engine!();
    let solo = engine.generate_greedy(&[b"hello world".to_vec()], 8).unwrap();
    let pair = engine
        .generate_greedy(&[b"hello world".to_vec(), b"and the cost ".to_vec()], 8)
        .unwrap();
    assert_eq!(solo[0], pair[0], "batch lane 0 must match solo run");
}

#[test]
fn decode_respects_max_seq() {
    let engine = require_engine!();
    let m = &engine.manifest;
    // Budget: max_seq - prefill_seq decode steps available.
    let budget = m.max_seq - m.prefill_seq;
    let out = engine
        .generate_greedy(&[vec![b'a'; m.prefill_seq]], budget + 50)
        .unwrap();
    assert!(
        out[0].len() <= budget + 1,
        "generated {} > budget {}",
        out[0].len(),
        budget
    );
}

#[test]
fn server_serves_batched_workload_with_sla_metrics() {
    let engine = require_engine!();
    let mut server = Server::new(engine, ServerConfig::default());
    let reqs: Vec<ChatRequest> = (0..6)
        .map(|i| ChatRequest::new(i, format!("request number {i} says "), 8))
        .collect();
    let responses = server.run_workload(reqs).unwrap();
    assert_eq!(responses.len(), 6);
    for r in &responses {
        assert!(!r.rejected);
        assert_eq!(r.tokens, 8);
        assert!(r.ttft_s >= 0.0 && r.e2e_s >= r.ttft_s);
    }
    let report = server.metrics.report();
    assert!(report.contains("server_requests 6"), "{report}");
    assert!(report.contains("server_tokens_out 48"), "{report}");
}

#[test]
fn multi_turn_session_accumulates_history() {
    let engine = require_engine!();
    let mut server = Server::new(engine, ServerConfig::default());

    let mut t1 = ChatRequest::new(1, "first turn. ", 6);
    t1.session = Some(42);
    let r1 = server.run_workload(vec![t1]).unwrap();

    // Second turn in the same session vs a fresh session: same input,
    // different context => (almost surely) different continuation.
    let mut t2_same = ChatRequest::new(2, "next turn. ", 6);
    t2_same.session = Some(42);
    let r2 = server.run_workload(vec![t2_same]).unwrap();

    assert_eq!(r1.len(), 1);
    assert_eq!(r2.len(), 1);
    assert_eq!(r2[0].tokens, 6);
}

#[test]
fn sampling_temperature_produces_variation() {
    let engine = require_engine!();
    let mut server = Server::new(engine, ServerConfig::default());
    let mut reqs = Vec::new();
    for i in 0..4 {
        let mut r = ChatRequest::new(i, "variation test ", 10);
        r.temperature = 1.2;
        reqs.push(r);
    }
    let responses = server.run_workload(reqs).unwrap();
    // Different request ids seed different samplers: expect >=2 distinct
    // outputs across 4 hot-temperature runs of the same prompt.
    let distinct: std::collections::BTreeSet<Vec<u8>> =
        responses.iter().map(|r| r.output.clone()).collect();
    assert!(distinct.len() >= 2, "no sampling variation");
}
