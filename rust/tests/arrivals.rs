//! Streaming-ingestion integration suite: the agent-DAG simulator must
//! produce *identical* reports whether a workload arrives as a
//! materialized slice (`DagSim::run`) or is pulled lazily from an
//! [`ArrivalProcess`] (`DagSim::run_stream`) — `SimReport` derives
//! `PartialEq` exactly so this equivalence is pinned at full f64
//! precision. On top of that: constant-memory evidence (the working
//! set tracks concurrency, not request count) and determinism of whole
//! streamed runs under a seed. Bit-level golden pins of the processes
//! themselves live next to the generators in
//! `cluster/arrivals.rs`.

use agentic_hetero::cluster::arrivals::{Diurnal, FlashCrowd, Poisson, Replay};
use agentic_hetero::cluster::dag::DagSim;
use agentic_hetero::cluster::sim::{simulate_stream, SimReport};
use agentic_hetero::cluster::trace::{generate, voice_agent, Request, TraceConfig};
use agentic_hetero::plan::presets;
use agentic_hetero::plan::ExecutionPlan;

fn tc(n: usize, rate: f64, seed: u64) -> TraceConfig {
    TraceConfig {
        n_requests: n,
        rate,
        isl_mean: 256,
        osl_mean: 48,
        sigma: 0.4,
        seed,
    }
}

fn preset_plans() -> Vec<ExecutionPlan> {
    vec![
        presets::mixed_generation("8b-fp16", "H100", "A100", 2, 2),
        presets::shared_prefix_fanout("8b-fp16", "H100", 4),
        presets::homogeneous("8b-fp16", "H100", 2),
    ]
}

#[test]
fn replay_equivalence_across_presets() {
    // `run(&trace)` and `run_stream(Replay)` must agree on every field
    // of the report, for every shipped preset topology.
    let trace = generate(&tc(192, 12.0, 9));
    for plan in preset_plans() {
        let slice = DagSim::new(&plan).unwrap().run(&trace).unwrap();
        let mut replay = Replay::new(&trace);
        let stream = simulate_stream(&plan, &mut replay).unwrap();
        assert_eq!(
            slice, stream,
            "plan `{}` diverges between slice and streaming ingestion",
            plan.agent
        );
    }
}

#[test]
fn replay_equivalence_on_voice_trace() {
    // Voice traces exercise pre_s/post_s host stages; the multi-node
    // DAG is where slot recycling could skew attribution.
    let trace = voice_agent(&tc(128, 8.0, 21));
    let plan = presets::mixed_generation("8b-fp16", "H100", "A100", 2, 2);
    let slice = DagSim::new(&plan).unwrap().run(&trace).unwrap();
    let mut replay = Replay::new(&trace);
    let stream = simulate_stream(&plan, &mut replay).unwrap();
    assert_eq!(slice, stream);
}

#[test]
fn live_poisson_process_equals_materialized_trace() {
    // Two ingestion paths of the *same* workload: a collected Poisson
    // trace through `run`, and a fresh process pulled live through
    // `run_stream`. The process is pinned bit-identical to
    // `trace::generate`, so the reports must match exactly.
    let plan = presets::mixed_generation("8b-fp16", "H100", "A100", 2, 2);
    let cfg = tc(256, 16.0, 4);
    let trace: Vec<Request> = Poisson::new(&cfg).unwrap().collect();
    let slice = DagSim::new(&plan).unwrap().run(&trace).unwrap();
    let mut live = Poisson::new(&cfg).unwrap();
    let stream = simulate_stream(&plan, &mut live).unwrap();
    assert_eq!(slice, stream);
}

#[test]
fn streaming_memory_tracks_concurrency_not_request_count() {
    // A diurnal stream an order of magnitude longer than anything the
    // simulator holds in flight: both high-watermarks must stay far
    // below n, or ingestion is materializing the future.
    let n = 4000;
    let plan = presets::mixed_generation("8b-fp16", "H100", "A100", 2, 2);
    let mut src = Diurnal::daily(&tc(n, 4.0, 1), 0.5).unwrap();
    let mut sim = DagSim::new(&plan).unwrap();
    let report = sim.run_stream(&mut src).unwrap();
    assert_eq!(report.n_requests, n, "streamed requests were dropped");
    let d = sim.last_detail().unwrap();
    assert!(
        d.inflight_peak < n / 10,
        "inflight peak {} scales with request count {n}",
        d.inflight_peak
    );
    assert!(
        d.event_queue_peak < n / 10,
        "event-queue peak {} scales with request count {n}",
        d.event_queue_peak
    );
}

#[test]
fn streamed_runs_are_deterministic_under_seed() {
    let plan = presets::homogeneous("8b-fp16", "H100", 2);
    let run = |seed: u64| -> SimReport {
        let mut src =
            FlashCrowd::periodic(&tc(300, 6.0, seed), 20.0, 5.0, 4.0).unwrap();
        simulate_stream(&plan, &mut src).unwrap()
    };
    // Same seed → identical report; different seed → a different
    // workload (arrival jitter moves the makespan).
    assert_eq!(run(42), run(42));
    assert_ne!(run(42), run(43));
}
