//! Golden test for the `trace-report` pipeline on a two-generation
//! trace: a hand-built span set (one request served by the H100
//! decode generation, one by the A100 generation behind a cross-chassis
//! KV hop) goes through the exact path the CLI uses — Chrome
//! trace-event export, byte round-trip, span recovery, critical-path
//! attribution — and the rendered table must match character for
//! character. Any change to the bucket math, the group aggregation, or
//! the table format shows up as a diff against GOLDEN.

use agentic_hetero::obs::critical_path::attribute_all;
use agentic_hetero::obs::trace::{spans_from_chrome_json, to_chrome_json, Span, SpanKind};
use agentic_hetero::util::json::Json;

fn span(
    request: u64,
    node: i64,
    kind: SpanKind,
    group: &str,
    t_start: f64,
    t_end: f64,
    parent: i64,
    queue_wait: f64,
) -> Span {
    Span {
        request,
        node,
        kind,
        group: group.into(),
        chassis: 0,
        t_start,
        t_end,
        parent,
        queue_wait,
    }
}

/// Two requests, one per decode generation:
///
/// * request 0 (H100): 0.1 s admission, prefill 0.1→0.3, decode
///   0.3→1.0 — fully explicit, coverage 100%;
/// * request 1 (A100): prefill 0.0→0.5, KV hop 0.5→0.9 into the A100
///   chassis, a 0.1 s unspanned gap, decode 1.0→2.0 — coverage 95%.
fn two_generation_trace() -> Vec<Span> {
    let h100 = "decode H100 tp1 pp1 b16";
    let a100 = "decode A100 tp1 pp1 b16";
    let pre = "prefill H100 tp1 pp1 b8";
    vec![
        span(0, -1, SpanKind::Request, "", 0.0, 1.0, -1, 0.1),
        span(0, 1, SpanKind::Prefill, pre, 0.1, 0.3, -1, 0.0),
        span(0, 2, SpanKind::Decode, h100, 0.3, 1.0, 1, 0.0),
        span(1, -1, SpanKind::Request, "", 0.0, 2.0, -1, 0.0),
        span(1, 1, SpanKind::Prefill, pre, 0.0, 0.5, -1, 0.0),
        span(1, 2, SpanKind::KvTransfer, a100, 0.5, 0.9, 1, 0.0),
        span(1, 2, SpanKind::Decode, a100, 1.0, 2.0, 1, 0.0),
    ]
}

const GOLDEN: &str = "\
2 requests, e2e total 3.000s, explicit coverage 96.7% (worst request 95.0%)
group                                     queue      prefill       decode  kv_transfer         host      tool_io        total
(admission)                              0.100s       0.000s       0.000s       0.000s       0.000s       0.000s       0.100s
decode A100 tp1 pp1 b16                  0.100s       0.000s       1.000s       0.400s       0.000s       0.000s       1.500s
decode H100 tp1 pp1 b16                  0.000s       0.000s       0.700s       0.000s       0.000s       0.000s       0.700s
prefill H100 tp1 pp1 b8                  0.000s       0.700s       0.000s       0.000s       0.000s       0.000s       0.700s
TOTAL                                    0.200s       0.700s       1.700s       0.400s       0.000s       0.000s       3.000s
share of e2e                               6.7%        23.3%        56.7%        13.3%         0.0%         0.0%
";

#[test]
fn trace_report_renders_the_golden_two_generation_table() {
    let spans = two_generation_trace();

    // The CLI path: export → serialize → reparse → recover → attribute.
    let doc = to_chrome_json(&spans);
    let text = doc.to_string();
    let reparsed = Json::parse(&text).expect("trace file parses");
    assert_eq!(reparsed.to_string(), text, "export is byte-stable");
    let recovered = spans_from_chrome_json(&reparsed).expect("spans recover");
    assert_eq!(recovered, spans, "lossless span round-trip");

    let attr = attribute_all(&recovered);
    assert_eq!(attr.requests, 2);
    assert_eq!(attr.table(), GOLDEN);

    // The attribution itself round-trips through JSON too (the form
    // that rides inside orchestrator timeline windows).
    let back = agentic_hetero::obs::critical_path::SlaAttribution::from_json(&attr.to_json())
        .expect("attribution json round-trips");
    assert_eq!(back, attr);
}
