//! Cross-validation: the discrete-event simulator and the analytic
//! Figure-8/9 explorer use the same roofline calibration, so their
//! predictions must agree in shape — decode-bound throughput, TBT
//! levels, and the heterogeneous-pair cost ordering.

use agentic_hetero::cluster::sim::{pair_placement, ClusterSim};
use agentic_hetero::cluster::trace::{generate, TraceConfig};
use agentic_hetero::cost::hardware::{by_name, DeviceSpec};
use agentic_hetero::cost::model_profile::llama3_8b;
use agentic_hetero::cost::roofline::{decode_step_time, Efficiency, Parallelism};
use agentic_hetero::cost::Precision;
use agentic_hetero::opt::parallelism::{best_config, ExploreOpts, SeqShape, SlaMode};
use agentic_hetero::transport::fabric::Fabric;

fn run_pair(prefill: &DeviceSpec, decode: &DeviceSpec, decode_batch: u64, rate: f64) -> agentic_hetero::cluster::sim::SimReport {
    let placement = pair_placement(
        prefill,
        Parallelism { tp: 1, pp: 1 },
        1,
        8,
        decode,
        Parallelism { tp: 1, pp: 1 },
        1,
        decode_batch,
    );
    let fabric = Fabric::new(4, 8, prefill.scaleup_bw_gbps, 400.0);
    let mut sim = ClusterSim::new(llama3_8b(Precision::Fp16), placement, fabric);
    let trace = generate(&TraceConfig {
        n_requests: 128,
        rate,
        isl_mean: 512,
        osl_mean: 128,
        sigma: 0.0,
        seed: 11,
    });
    sim.run(&trace).unwrap()
}

#[test]
fn simulated_tbt_matches_roofline_step_time() {
    // Saturated decode at fixed batch: the simulator's TBT must sit near
    // the analytic decode_step_time at the same batch/context.
    let h100 = by_name("H100").unwrap();
    let report = run_pair(&h100, &h100, 32, 50.0); // overload => full batches
    let m = llama3_8b(Precision::Fp16);
    let analytic = decode_step_time(
        &m,
        &h100,
        Parallelism { tp: 1, pp: 1 },
        512 + 64,
        32,
        &Efficiency::default(),
    )
    .total();
    let ratio = report.tbt_p50_s / analytic;
    assert!(
        (0.5..2.0).contains(&ratio),
        "sim TBT {} vs analytic {} (ratio {ratio})",
        report.tbt_p50_s,
        analytic
    );
}

#[test]
fn simulator_reproduces_gaudi_decode_advantage() {
    // The fig-8 decode story: at equal load, Gaudi3 decode yields lower
    // $/Mtok than H100 decode (H100 prefill both sides).
    let h100 = by_name("H100").unwrap();
    let gaudi = by_name("Gaudi3").unwrap();
    let homo = run_pair(&h100, &h100, 32, 20.0);
    let hetero = run_pair(&h100, &gaudi, 32, 20.0);
    assert!(
        hetero.usd_per_mtok < homo.usd_per_mtok,
        "hetero ${} should beat homo ${}",
        hetero.usd_per_mtok,
        homo.usd_per_mtok
    );
}

#[test]
fn simulated_cost_ordering_matches_explorer() {
    // Rank three pairs by simulated $/Mtok and by the analytic
    // explorer's tokens/s/$; orders must agree.
    let pairs = [("H100", "H100"), ("H100", "Gaudi3"), ("A100", "A40")];
    let opts = ExploreOpts::default();
    let m = llama3_8b(Precision::Fp16);
    let shape = SeqShape { isl: 512, osl: 128 };

    let mut sim_cost = Vec::new();
    let mut analytic_cost = Vec::new();
    for (p, d) in pairs {
        let pd = by_name(p).unwrap();
        let dd = by_name(d).unwrap();
        let rep = run_pair(&pd, &dd, 32, 30.0);
        sim_cost.push((format!("{p}::{d}"), rep.usd_per_mtok));
        let cfg = best_config(&m, &pd, &dd, shape, SlaMode::Throughput, &opts).unwrap();
        analytic_cost.push((format!("{p}::{d}"), cfg.usd_per_mtok));
    }
    let order = |mut v: Vec<(String, f64)>| {
        v.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        v.into_iter().map(|(n, _)| n).collect::<Vec<_>>()
    };
    assert_eq!(
        order(sim_cost.clone()),
        order(analytic_cost.clone()),
        "sim {sim_cost:?} vs analytic {analytic_cost:?}"
    );
}

#[test]
fn overload_degrades_ttft_not_tbt() {
    // Queueing theory sanity: overload inflates TTFT (queue) while TBT
    // (a property of the decode round) stays near its saturated level.
    let h100 = by_name("H100").unwrap();
    let light = run_pair(&h100, &h100, 32, 2.0);
    let heavy = run_pair(&h100, &h100, 32, 80.0);
    assert!(heavy.ttft_p95_s > 3.0 * light.ttft_p95_s);
    assert!(heavy.tbt_p95_s < 3.0 * light.tbt_p95_s.max(0.003));
}

#[test]
fn kv_transfer_traffic_scales_with_isl() {
    let h100 = by_name("H100").unwrap();
    let gaudi = by_name("Gaudi3").unwrap();
    let short = {
        let placement = pair_placement(
            &h100, Parallelism { tp: 1, pp: 1 }, 1, 8,
            &gaudi, Parallelism { tp: 1, pp: 1 }, 1, 32,
        );
        let mut sim = ClusterSim::new(
            llama3_8b(Precision::Fp16),
            placement,
            Fabric::new(4, 8, 900.0, 400.0),
        );
        let trace = generate(&TraceConfig {
            n_requests: 64, rate: 8.0, isl_mean: 256, osl_mean: 32, sigma: 0.0, seed: 2,
        });
        sim.run(&trace).unwrap().kv_bytes_moved
    };
    let long = {
        let placement = pair_placement(
            &h100, Parallelism { tp: 1, pp: 1 }, 1, 8,
            &gaudi, Parallelism { tp: 1, pp: 1 }, 1, 32,
        );
        let mut sim = ClusterSim::new(
            llama3_8b(Precision::Fp16),
            placement,
            Fabric::new(4, 8, 900.0, 400.0),
        );
        let trace = generate(&TraceConfig {
            n_requests: 64, rate: 8.0, isl_mean: 1024, osl_mean: 32, sigma: 0.0, seed: 2,
        });
        sim.run(&trace).unwrap().kv_bytes_moved
    };
    let ratio = long / short;
    assert!((3.5..4.5).contains(&ratio), "Eq.3 linearity: ratio {ratio}");
}
