//! The tentpole contract: an `ExecutionPlan` produced by
//! `planner::plan` round-trips through `util::json` and is consumed
//! *unmodified* by both the cluster simulator (`simulate_plan`) and the
//! server configuration — planner → simulator → server all speak one
//! plan language.

use agentic_hetero::agents;
use agentic_hetero::cluster::sim::{simulate_plan, ClusterSim};
use agentic_hetero::cluster::trace::{generate, voice_agent as voice_trace, TraceConfig};
use agentic_hetero::opt::assignment::Sla;
use agentic_hetero::plan::{ExecutionPlan, Role, Stage};
use agentic_hetero::planner::plan::{Planner, PlannerConfig};
use agentic_hetero::server::ServerConfig;

fn voice_plan(sla: Sla) -> ExecutionPlan {
    let g = agents::voice_agent("8b-fp16", 512, 256);
    let mut cfg = PlannerConfig::default();
    cfg.sla = sla;
    Planner::new(cfg).plan(&g).unwrap()
}

#[test]
fn planner_output_round_trips_through_json() {
    let plan = voice_plan(Sla::EndToEnd(3.0));
    let text = plan.to_json_string();
    let back = ExecutionPlan::parse_json(&text).unwrap();
    assert_eq!(back, plan, "JSON round-trip must be lossless");
    // Serialization is deterministic (diffable artifacts).
    assert_eq!(back.to_json_string(), text);
}

#[test]
fn round_tripped_plan_simulates_the_voice_agent_dag() {
    let plan = voice_plan(Sla::EndToEnd(3.0));
    let replayed = ExecutionPlan::parse_json(&plan.to_json_string()).unwrap();

    // The plan carries the whole agent DAG: CPU stages and both LLM
    // stages must be present and consistently bound.
    assert!(replayed.bindings.iter().any(|b| b.op == "stt.transcribe"));
    assert!(replayed.bindings.iter().any(|b| b.op == "tts.synthesize"));
    assert_eq!(replayed.class_of("stt.transcribe"), Some("CPU"));
    assert!(replayed
        .bindings
        .iter()
        .any(|b| b.stage == Stage::LlmPrefill));
    assert!(replayed.bindings.iter().any(|b| b.stage == Stage::LlmDecode));

    let trace = voice_trace(&TraceConfig {
        n_requests: 64,
        rate: 4.0,
        isl_mean: 512,
        osl_mean: 64,
        sigma: 0.3,
        seed: 5,
    });
    let report = simulate_plan(&replayed, &trace).unwrap();
    assert_eq!(report.n_requests, 64);
    assert!(report.output_tokens > 0);
    assert!(report.tokens_per_s > 0.0);
    // The voice agent's STT floor (≥ ~0.1 s) must show up in TTFT —
    // evidence the CPU stages actually execute in the DAG.
    assert!(
        report.ttft_p50_s > 0.05,
        "TTFT {} too small for a DAG with CPU pre-stages",
        report.ttft_p50_s
    );
    assert!(report.e2e_p50_s > report.ttft_p50_s);
}

#[test]
fn same_plan_configures_the_server() {
    let plan = voice_plan(Sla::EndToEnd(3.0));
    let replayed = ExecutionPlan::parse_json(&plan.to_json_string()).unwrap();
    let cfg = ServerConfig::from_plan(&replayed);
    assert_eq!(cfg.batch.buckets, replayed.batching.buckets);
    assert_eq!(cfg.admission.rate, replayed.admission.rate);
    assert_eq!(
        cfg.admission.max_queue_depth,
        replayed.admission.max_queue_depth
    );
}

#[test]
fn flat_simulator_builds_from_the_same_plan() {
    let plan = voice_plan(Sla::EndToEnd(3.0));
    let mut sim = ClusterSim::from_plan(&plan).unwrap();
    let trace = generate(&TraceConfig {
        n_requests: 32,
        rate: 4.0,
        isl_mean: 512,
        osl_mean: 32,
        sigma: 0.0,
        seed: 3,
    });
    let report = sim.run(&trace).unwrap();
    assert_eq!(report.n_requests, 32);
    assert!(report.tokens_per_s > 0.0);
}

#[test]
fn multi_llm_agent_dag_executes_every_inference() {
    // The supervisor pattern inlines 2 worker LLMs + 1 merge LLM: the
    // DAG simulator must schedule all three prefill/decode pairs per
    // request.
    let g = agentic_hetero::agents::patterns::supervisor("8b-fp16", 2);
    let mut cfg = PlannerConfig::default();
    cfg.sla = Sla::None;
    let plan = Planner::new(cfg).plan(&g).unwrap();
    let n_decode = plan
        .bindings
        .iter()
        .filter(|b| b.stage == Stage::LlmDecode)
        .count();
    assert_eq!(n_decode, 3, "supervisor(2) exposes 3 LLM inferences");

    let trace = generate(&TraceConfig {
        n_requests: 16,
        rate: 2.0,
        isl_mean: 256,
        osl_mean: 16,
        sigma: 0.0,
        seed: 11,
    });
    let report = simulate_plan(&plan, &trace).unwrap();
    // Every decode stage emits osl tokens per request.
    assert_eq!(
        report.output_tokens,
        (16 * 16 * n_decode) as u64,
        "all LLM inferences must run"
    );
}

#[test]
fn plan_pipelines_cover_all_llm_classes() {
    let plan = voice_plan(Sla::None);
    for b in &plan.bindings {
        match b.stage {
            Stage::LlmPrefill => assert!(plan
                .pipelines
                .iter()
                .any(|p| p.role == Role::Prefill && p.device == b.class)),
            Stage::LlmDecode => assert!(plan
                .pipelines
                .iter()
                .any(|p| p.role == Role::Decode && p.device == b.class)),
            Stage::Cpu => {}
        }
    }
}

#[test]
fn saved_plan_file_replays() {
    // Full save → load → simulate loop through the filesystem, as the
    // CLI (`plan --out` / `simulate --plan`) does.
    let plan = voice_plan(Sla::EndToEnd(3.0));
    let dir = std::env::temp_dir();
    let path = dir.join("agentic_hetero_test.plan.json");
    std::fs::write(&path, plan.to_json_string()).unwrap();
    let loaded =
        ExecutionPlan::parse_json(&std::fs::read_to_string(&path).unwrap()).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(loaded, plan);
    let trace = generate(&TraceConfig {
        n_requests: 8,
        rate: 2.0,
        isl_mean: 512,
        osl_mean: 16,
        sigma: 0.0,
        seed: 2,
    });
    assert!(simulate_plan(&loaded, &trace).is_ok());
}
