//! Property tests for the live host pool and agent-DAG dispatcher:
//!
//! * arbitrary task storms complete, and concurrently-running tasks
//!   never exceed the pool's capacity;
//! * arbitrary CPU-only agent DAGs executed through the live server
//!   never deadlock and always respect dependency order;
//! * bounded workers never exceed the plan's host capacity, across
//!   resizes.
//!
//! Gated off pjrt builds: the server side runs on the synthetic engine.

#![cfg(not(feature = "pjrt"))]

use std::sync::mpsc;
use std::thread;
use std::time::Duration;

use agentic_hetero::plan::{
    AdmissionPolicy, BatchPolicy, ExecutionPlan, FabricSpec, NodeBinding, SlaSpec, Stage,
};
use agentic_hetero::runtime::Engine;
use agentic_hetero::server::{ChatRequest, ChatResponse, HostPool, HostTask, Server};
use agentic_hetero::util::prop::check_cases;
use agentic_hetero::util::rng::Rng;

// ---------------------------------------------------------------------
// Pool-level properties
// ---------------------------------------------------------------------

#[test]
fn pool_storms_complete_within_capacity() {
    check_cases("pool-storm", 16, &mut |rng: &mut Rng| {
        let capacity = rng.range(1, 5) as usize; // 1..=4
        let n_tasks = rng.range(1, 25) as usize; // 1..=24
        let (done_tx, done_rx) = mpsc::channel();
        let pool = HostPool::new(capacity, done_tx);
        for i in 0..n_tasks {
            let sleep_us = rng.range(0, 1500);
            pool.submit(HostTask {
                req: i as u64,
                node: 0,
                epoch: 0,
                work: Box::new(move || {
                    if sleep_us > 0 {
                        thread::sleep(Duration::from_micros(sleep_us));
                    }
                    Ok(Vec::new())
                }),
            });
        }
        for _ in 0..n_tasks {
            let d = done_rx
                .recv_timeout(Duration::from_secs(20))
                .expect("pool must drain every task");
            assert!(d.result.is_ok());
        }
        assert_eq!(pool.completed(), n_tasks as u64);
        assert!(
            pool.high_watermark() <= capacity as u64,
            "watermark {} exceeded capacity {capacity}",
            pool.high_watermark()
        );
    });
}

#[test]
fn pool_resize_preserves_capacity_bound() {
    check_cases("pool-resize", 8, &mut |rng: &mut Rng| {
        let (done_tx, done_rx) = mpsc::channel();
        let first = rng.range(1, 4) as usize;
        let mut pool = HostPool::new(first, done_tx);
        let mut max_cap = first;
        let mut total = 0u64;
        for _round in 0..3 {
            let cap = rng.range(1, 5) as usize;
            pool.resize(cap);
            max_cap = max_cap.max(cap);
            let n = rng.range(1, 8);
            for i in 0..n {
                pool.submit(HostTask {
                    req: i,
                    node: 0,
                    epoch: 0,
                    work: Box::new(|| {
                        thread::sleep(Duration::from_micros(200));
                        Ok(Vec::new())
                    }),
                });
            }
            for _ in 0..n {
                done_rx
                    .recv_timeout(Duration::from_secs(20))
                    .expect("resized pool must still drain");
            }
            total += n;
        }
        assert_eq!(pool.completed(), total);
        // Shrinks drain gracefully, so the bound is the max capacity
        // the pool ever ran at.
        assert!(pool.high_watermark() <= max_cap as u64);
    });
}

// ---------------------------------------------------------------------
// DAG-level properties (through the live server)
// ---------------------------------------------------------------------

/// Random CPU-only plan: every node depends on a random subset of
/// earlier nodes, so any topology the generator emits is valid.
fn random_cpu_plan(rng: &mut Rng) -> ExecutionPlan {
    let n_nodes = rng.range(1, 8) as usize; // 1..=7
    let mut bindings = Vec::with_capacity(n_nodes);
    for i in 0..n_nodes {
        let mut deps = Vec::new();
        for j in 0..i {
            if rng.bool(0.4) {
                deps.push(j);
            }
        }
        bindings.push(NodeBinding {
            op: format!("tool.op{i}"),
            class: "CPU".into(),
            stage: Stage::Cpu,
            latency_s: 0.0002 + rng.f64() * 0.0015,
            cost_usd: 0.0,
            deps,
            xfer_bytes: 0.0,
            token_fraction: 1.0,
            prefix_overlap: 0.0,
        });
    }
    ExecutionPlan {
        agent: "prop_agent".into(),
        model: String::new(),
        sla: SlaSpec::None,
        bindings,
        pipelines: vec![],
        batching: BatchPolicy::default(),
        admission: AdmissionPolicy::default(),
        fabric: FabricSpec::default(),
        cpu_workers: rng.range(1, 4) as u32, // 1..=3
        cost_usd: 0.0,
        latency_s: 0.01,
        pass_log: vec![],
    }
}

/// Run a workload with a watchdog so a scheduling deadlock fails the
/// test instead of hanging CI.
fn run_with_watchdog(
    mut server: Server,
    reqs: Vec<ChatRequest>,
) -> (Server, Vec<ChatResponse>) {
    let (done_tx, done_rx) = mpsc::channel();
    let handle = thread::spawn(move || {
        let out = server.run_workload(reqs);
        let _ = done_tx.send(());
        (server, out)
    });
    match done_rx.recv_timeout(Duration::from_secs(30)) {
        Ok(()) => {
            let (server, out) = handle.join().expect("serve thread panicked");
            (server, out.expect("serve must not error"))
        }
        Err(_) => panic!("DAG execution deadlocked (watchdog fired)"),
    }
}

#[test]
fn arbitrary_dags_never_deadlock_and_respect_dependency_order() {
    check_cases("dag-order", 12, &mut |rng: &mut Rng| {
        let plan = random_cpu_plan(rng);
        plan.validate().expect("generator emits valid plans");
        let server = Server::from_plan(Engine::synthetic_default(), &plan).unwrap();
        let n_req = rng.range(1, 6);
        let reqs: Vec<ChatRequest> = (0..n_req)
            .map(|i| ChatRequest::new(i, "p", 4).with_agent("prop_agent"))
            .collect();
        let (server, responses) = run_with_watchdog(server, reqs);

        assert_eq!(responses.len(), n_req as usize, "no request may be lost");
        for r in &responses {
            assert!(r.is_ok(), "{:?}", r.error);
            assert_eq!(
                r.stages.len(),
                plan.bindings.len(),
                "every node must execute exactly once"
            );
            for s in &r.stages {
                for &d in &plan.bindings[s.node].deps {
                    let dep = r
                        .stages
                        .iter()
                        .find(|x| x.node == d)
                        .expect("dependency must have executed");
                    assert!(
                        dep.end_s <= s.start_s + 1e-9,
                        "node {} started at {} before dep {} finished at {}",
                        s.node,
                        s.start_s,
                        d,
                        dep.end_s
                    );
                }
            }
        }
        assert!(
            server.host_high_watermark() <= plan.cpu_workers as u64,
            "pool ran {} stages concurrently with capacity {}",
            server.host_high_watermark(),
            plan.cpu_workers
        );
    });
}

#[test]
fn wide_fanout_respects_plan_host_capacity() {
    // One root fanning out to many parallel tools on a 2-slot pool:
    // the pool must serialize, never exceeding the plan's capacity.
    let mut bindings = vec![NodeBinding {
        op: "io.input".into(),
        class: "CPU".into(),
        stage: Stage::Cpu,
        latency_s: 0.0002,
        cost_usd: 0.0,
        deps: vec![],
        xfer_bytes: 0.0,
        token_fraction: 1.0,
        prefix_overlap: 0.0,
    }];
    for i in 0..6 {
        bindings.push(NodeBinding {
            op: format!("tool.fan{i}"),
            class: "CPU".into(),
            stage: Stage::Cpu,
            latency_s: 0.002,
            cost_usd: 0.0,
            deps: vec![0],
            xfer_bytes: 0.0,
            token_fraction: 1.0,
            prefix_overlap: 0.0,
        });
    }
    let plan = ExecutionPlan {
        agent: "fan_agent".into(),
        model: String::new(),
        sla: SlaSpec::None,
        bindings,
        pipelines: vec![],
        batching: BatchPolicy::default(),
        admission: AdmissionPolicy::default(),
        fabric: FabricSpec::default(),
        cpu_workers: 2,
        cost_usd: 0.0,
        latency_s: 0.01,
        pass_log: vec![],
    };
    let server = Server::from_plan(Engine::synthetic_default(), &plan).unwrap();
    let reqs: Vec<ChatRequest> = (0..4u64)
        .map(|i| ChatRequest::new(i, "fan", 4).with_agent("fan_agent"))
        .collect();
    let (server, responses) = run_with_watchdog(server, reqs);
    assert_eq!(responses.len(), 4);
    for r in &responses {
        assert!(r.is_ok());
        assert_eq!(r.stages.len(), 7);
    }
    assert!(
        server.host_high_watermark() <= 2,
        "watermark {} exceeded the plan's 2 cpu workers",
        server.host_high_watermark()
    );
}
