//! Planner integration: the full slow path (IR pipeline → cost
//! annotation → assignment) over every Figure-1 agent pattern, SLA
//! sweeps, feedback-driven replanning, and autoscale + migration
//! round-trips.

use agentic_hetero::agents::{self, patterns};
use agentic_hetero::opt::assignment::Sla;
use agentic_hetero::planner::autoscale::{Autoscaler, AutoscalerConfig, ScaleDecision};
use agentic_hetero::planner::feedback::ProfileStore;
use agentic_hetero::planner::migration::{plan_migration, MigrationStep, RoleMap};
use agentic_hetero::planner::plan::{Planner, PlannerConfig};

fn planner(sla: Sla) -> Planner {
    let mut cfg = PlannerConfig::default();
    cfg.sla = sla;
    Planner::new(cfg)
}

#[test]
fn all_fig1_patterns_plan_successfully() {
    let graphs = vec![
        patterns::single_agent("8b-fp16", &["search", "calculator"]),
        patterns::peer_network("8b-fp16", 3),
        patterns::supervisor("8b-fp16", 3),
        patterns::agent_as_tool("8b-fp16"),
        patterns::custom("8b-fp16"),
    ];
    for g in graphs {
        let plan = planner(Sla::None)
            .plan(&g)
            .unwrap_or_else(|e| panic!("{}: {e}", g.name));
        assert!(!plan.bindings.is_empty(), "{}", g.name);
        assert!(plan.cost_usd.is_finite());
        // Every placement is a real class.
        for b in &plan.bindings {
            assert!(
                ["A40", "A100", "Gaudi3", "MI300x", "H100", "B200", "CPU"]
                    .contains(&b.class.as_str()),
                "unknown class {}",
                b.class
            );
        }
        // The lowered plan is structurally valid and self-describing.
        plan.validate().unwrap();
    }
}

#[test]
fn sla_sweep_traces_cost_latency_frontier() {
    // As the SLA tightens, cost must be non-decreasing and latency
    // non-increasing (a Pareto frontier walk).
    let g = agents::voice_agent("70b-fp8", 1024, 256);
    let loose = planner(Sla::None).plan(&g).unwrap();
    let mut last_cost = loose.cost_usd;
    let mut last_latency = loose.latency_s;
    let mut tightened = 0;
    for f in [0.95, 0.90, 0.85] {
        let sla = loose.latency_s * f;
        match planner(Sla::EndToEnd(sla)).plan(&g) {
            Ok(p) => {
                assert!(p.latency_s <= sla + 1e-9);
                assert!(p.cost_usd >= last_cost - 1e-12, "cost must not drop");
                assert!(p.latency_s <= last_latency + 1e-9);
                last_cost = p.cost_usd;
                last_latency = p.latency_s;
                tightened += 1;
            }
            Err(_) => break, // below the feasible floor
        }
    }
    assert!(tightened >= 1, "no feasible tightening at all");
}

#[test]
fn moe_agent_plans_with_expert_parallelism() {
    use agentic_hetero::ir::attr::Attr;
    use agentic_hetero::ir::GraphBuilder;

    let mut b = GraphBuilder::new("moe_agent");
    let x = b.op("io.input", &[]);
    let y = b.op_with(
        "llm.infer",
        &[x],
        &[
            ("model", "70b-fp8".into()),
            ("experts", Attr::Int(4)),
            ("top_k", Attr::Int(2)),
        ],
    );
    b.op("io.output", &[y]);
    let g = b.finish();

    let plan = planner(Sla::None).plan(&g).unwrap();
    // Expert decomposition happened and each expert got an accelerator.
    let experts: Vec<_> = plan
        .bindings
        .iter()
        .filter(|b| b.op == "moe.expert_prefill")
        .collect();
    assert_eq!(experts.len(), 4);
    for b in experts {
        assert_ne!(b.class, "CPU");
    }
}

#[test]
fn feedback_store_flags_drift_for_replanning() {
    let mut store = ProfileStore::new(0.5);
    // Planner expected 50 ms prefill on H100; runtime observes 200 ms
    // (e.g. thermal throttling) — drift detection must fire.
    let mut expected = std::collections::BTreeMap::new();
    expected.insert(("llm.prefill".to_string(), "H100".to_string()), 0.05);
    for _ in 0..10 {
        store.observe("llm.prefill", "H100", 0.2);
    }
    let drifted = store.drifted(&expected, 2.0);
    assert_eq!(drifted.len(), 1);
    let (op, class, exp, got) = &drifted[0];
    assert_eq!(op, "llm.prefill");
    assert_eq!(class, "H100");
    assert!(got / exp > 3.0);
}

#[test]
fn autoscale_then_migrate_roundtrip() {
    // Load spike: autoscaler grows decode pipelines 2 -> 3; migration
    // planner emits activate-before-drain steps for the fleet change.
    let mut scaler = Autoscaler::new(AutoscalerConfig::default(), 2);
    let mut grown = 2;
    for _ in 0..3 {
        if let ScaleDecision::ScaleUp(n) = scaler.observe(0.95) {
            grown += n;
        }
    }
    assert_eq!(grown, 3);

    let mut current = RoleMap::new();
    current.insert(("Gaudi3".into(), "decode".into()), 2);
    let mut target = RoleMap::new();
    target.insert(("Gaudi3".into(), "decode".into()), grown);
    let fabric = agentic_hetero::transport::fabric::Fabric::new(4, 8, 900.0, 400.0);
    let plan = plan_migration(&current, &target, 4e9, &fabric);
    assert_eq!(plan.steps.len(), 1);
    assert!(matches!(
        plan.steps[0],
        MigrationStep::Activate { count: 1, .. }
    ));
    assert_eq!(plan.kv_bytes, 0.0, "growth moves no KV");
}

#[test]
fn restricted_catalog_respected() {
    // A fleet with only A40s and CPUs: the LLM must land on A40 even
    // though better devices exist in the full catalog.
    let g = agents::rag_agent("8b-fp16", 512, 64, 4);
    let devices: Vec<_> = agentic_hetero::cost::hardware::catalog()
        .into_iter()
        .filter(|d| d.name == "A40")
        .collect();
    let p = Planner::new(PlannerConfig {
        sla: Sla::None,
        ..Default::default()
    })
    .with_devices(devices);
    let plan = p.plan(&g).unwrap();
    assert_eq!(plan.class_of("llm.prefill"), Some("A40"));
    assert_eq!(plan.class_of("llm.decode"), Some("A40"));
    // Every placement stays within the restricted fleet. (Light CPU-ish
    // ops may legitimately collocate on the A40 when the γ transfer
    // penalty exceeds the opex saving — the optimizer's call.)
    for b in &plan.bindings {
        assert!(
            b.class == "A40" || b.class == "CPU",
            "{} placed on {}, outside the fleet",
            b.op,
            b.class
        );
    }
    // The emitted pipelines live on the restricted fleet too.
    for pl in &plan.pipelines {
        assert_eq!(pl.device, "A40");
    }
}
