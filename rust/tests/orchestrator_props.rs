//! Property tests for the orchestration machinery: every migration
//! produced from a plan diff must keep per-role capacity non-negative
//! at each step and converge exactly to the target fleet; retargeted
//! plans must stay structurally valid; plans round-trip through JSON
//! with arbitrary token fractions.

use agentic_hetero::orchestrator::{
    capacity_trajectory, converges, lower_diff, retarget, shape_map_of,
};
use agentic_hetero::plan::{
    AdmissionPolicy, BatchPolicy, ExecutionPlan, FabricSpec, NodeBinding, PipelineBinding,
    PlanDiff, Role, SlaSpec, Stage,
};
use agentic_hetero::planner::migration::{plan_migration, RoleMap};
use agentic_hetero::transport::fabric::Fabric;
use agentic_hetero::util::prop::check;
use agentic_hetero::util::rng::Rng;

const DEVICES: [&str; 4] = ["H100", "Gaudi3", "A100", "MI300x"];
const ROLES: [&str; 2] = ["prefill", "decode"];

fn random_role_map(rng: &mut Rng) -> RoleMap {
    let mut m = RoleMap::new();
    for d in DEVICES {
        for r in ROLES {
            if rng.bool(0.6) {
                let n = rng.range(0, 6) as u32;
                if n > 0 {
                    m.insert((d.to_string(), r.to_string()), n);
                }
            }
        }
    }
    m
}

/// A small valid plan: H100 prefill + Gaudi3 decode (mirrors the
/// crate-internal test fixture, built from public types).
fn base_plan() -> ExecutionPlan {
    ExecutionPlan {
        agent: "props".into(),
        model: "8b-fp16".into(),
        sla: SlaSpec::EndToEnd(3.0),
        bindings: vec![
            NodeBinding {
                op: "io.input".into(),
                class: "CPU".into(),
                stage: Stage::Cpu,
                latency_s: 0.0005,
                cost_usd: 0.0,
                deps: vec![],
                xfer_bytes: 0.0,
                token_fraction: 1.0,
                prefix_overlap: 0.0,
            },
            NodeBinding {
                op: "llm.prefill".into(),
                class: "H100".into(),
                stage: Stage::LlmPrefill,
                latency_s: 0.05,
                cost_usd: 1e-5,
                deps: vec![0],
                xfer_bytes: 1e6,
                token_fraction: 1.0,
                prefix_overlap: 0.0,
            },
            NodeBinding {
                op: "llm.decode".into(),
                class: "Gaudi3".into(),
                stage: Stage::LlmDecode,
                latency_s: 0.5,
                cost_usd: 2e-5,
                deps: vec![1],
                xfer_bytes: 1e8,
                token_fraction: 1.0,
                prefix_overlap: 0.0,
            },
        ],
        pipelines: vec![
            PipelineBinding {
                role: Role::Prefill,
                device: "H100".into(),
                tp: 1,
                pp: 1,
                max_batch: 8,
                replicas: 1,
                chassis: 0,
            },
            PipelineBinding {
                role: Role::Decode,
                device: "Gaudi3".into(),
                tp: 1,
                pp: 1,
                max_batch: 32,
                replicas: 2,
                chassis: 1,
            },
        ],
        batching: BatchPolicy::default(),
        admission: AdmissionPolicy::default(),
        fabric: FabricSpec::default(),
        cpu_workers: 16,
        cost_usd: 3e-5,
        latency_s: 0.55,
        pass_log: vec![],
    }
}

#[test]
fn migration_steps_are_capacity_safe_and_convergent() {
    let fabric = Fabric::new(4, 8, 900.0, 400.0);
    check("migration-capacity-safe", |rng| {
        let cur = random_role_map(rng);
        let tgt = random_role_map(rng);
        let kv_per = rng.f64() * 4e9;
        let m = plan_migration(&cur, &tgt, kv_per, &fabric);

        // Replaying never drives any (device, role) capacity negative...
        let traj = capacity_trajectory(&cur, &m.steps)
            .expect("migration plan must be capacity-safe");
        assert_eq!(traj.len(), m.steps.len() + 1);
        // ...and converges to exactly the target fleet.
        assert!(
            converges(&cur, &tgt, &m.steps),
            "must land on target: cur={cur:?} tgt={tgt:?} steps={:?}",
            m.steps
        );
        // Cost bookkeeping is sane.
        assert!(m.kv_bytes >= 0.0 && m.kv_bytes.is_finite());
        assert!(m.est_duration_s >= 1.0 && m.est_duration_s.is_finite());
        // No change ⇒ no steps.
        let idle = plan_migration(&cur, &cur, kv_per, &fabric);
        assert!(idle.steps.is_empty());
    });
}

#[test]
fn retargeted_plans_stay_valid_and_their_migrations_converge() {
    check("retarget-valid-and-convergent", |rng| {
        let plan = base_plan();
        let pre = rng.range(0, 8) as u32;
        let dec = rng.range(0, 12) as u32;
        let target = retarget(&plan, pre, dec);
        target.validate().expect("retarget must stay valid");
        // At least one replica per role survives any request.
        assert!(target.pipelines.iter().all(|p| p.replicas >= 1));
        // Chassis are packed consecutively.
        let mut expect = 0u32;
        for p in &target.pipelines {
            assert_eq!(p.chassis, expect);
            expect += p.replicas;
        }
        // The diff lowers to a convergent, capacity-safe migration
        // (shape-granular: the capacity view the fleet actually matches).
        let kv = rng.f64() * 1e10;
        let m = lower_diff(&plan, &target, kv).unwrap();
        let cur = shape_map_of(&plan);
        let tgt = shape_map_of(&target);
        capacity_trajectory(&cur, &m.steps).expect("capacity-safe");
        assert!(converges(&cur, &tgt, &m.steps));
        // An empty diff yields an empty migration.
        if PlanDiff::between(&plan, &target).is_empty() {
            assert!(m.steps.is_empty());
        }
    });
}

#[test]
fn plan_json_round_trips_with_arbitrary_token_fractions() {
    check("plan-roundtrip-token-fraction", |rng| {
        let mut plan = base_plan();
        for b in &mut plan.bindings {
            // (0, 1] — the validated range.
            b.token_fraction = (rng.f64().max(1e-9)).min(1.0);
        }
        plan.validate().unwrap();
        let text = plan.to_json_string();
        let back = ExecutionPlan::parse_json(&text).unwrap();
        assert_eq!(back, plan);
        assert_eq!(back.to_json_string(), text);
    });
}

#[test]
fn diff_is_reflexively_empty_and_detects_mutations() {
    check("diff-detects-mutations", |rng| {
        let plan = base_plan();
        assert!(PlanDiff::between(&plan, &plan).is_empty());
        let mut other = plan.clone();
        // Mutate one tracked dimension at random; the diff must see it.
        match rng.range(0, 4) {
            0 => other.pipelines[1].replicas += rng.range(1, 4) as u32,
            1 => other.bindings[2].class = "H100".into(),
            2 => other.admission.rate *= 2.0,
            _ => other.cpu_workers += 1,
        }
        let d = PlanDiff::between(&plan, &other);
        assert!(!d.is_empty(), "mutation must surface in the diff");
    });
}
