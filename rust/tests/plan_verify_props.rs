//! Property suite for the static plan analyzer (`plan::verify`).
//!
//! Two invariants:
//!
//! 1. every shipped preset verifies clean (the same gate CI's
//!    `plan lint --deny-warn --presets` enforces), and
//! 2. a random single-field mutation of a clean plan is caught by the
//!    analyzer with the *expected* diagnostic code and severity — the
//!    pass stack has no blind spot across its five categories
//!    (topology, binding invariants, capacity, fabric, SLA).
//!
//! Case count follows `AH_PROP_CASES` (128 default; the nightly CI
//! sweep runs 4096).

use agentic_hetero::plan::presets;
use agentic_hetero::plan::verify;
use agentic_hetero::plan::{DiagReport, ExecutionPlan, Severity, SlaSpec};
use agentic_hetero::util::prop::check;
use agentic_hetero::util::rng::Rng;

fn clean_presets() -> Vec<(&'static str, ExecutionPlan)> {
    vec![
        (
            "mixed_generation",
            presets::mixed_generation("8b-fp16", "H100", "A100", 2, 2),
        ),
        (
            "shared_prefix_fanout",
            presets::shared_prefix_fanout("8b-fp16", "H100", 4),
        ),
        ("homogeneous", presets::homogeneous("8b-fp16", "H100", 2)),
    ]
}

#[test]
fn all_presets_verify_clean() {
    for (name, plan) in clean_presets() {
        let report = verify::verify(&plan);
        assert!(
            report.is_clean(),
            "preset {name} must lint clean:\n{}",
            report.table()
        );
        verify::ensure_loadable(&plan)
            .unwrap_or_else(|e| panic!("preset {name} must be loadable: {e}"));
    }
}

/// Pick a binding index with a non-empty dep list (every preset has
/// several).
fn binding_with_deps(plan: &ExecutionPlan, rng: &mut Rng) -> usize {
    let with: Vec<usize> = (0..plan.bindings.len())
        .filter(|&i| !plan.bindings[i].deps.is_empty())
        .collect();
    with[rng.index(with.len())]
}

/// Pick an LLM (non-CPU) binding index.
fn llm_binding(plan: &ExecutionPlan, rng: &mut Rng) -> usize {
    let llm: Vec<usize> = (0..plan.bindings.len())
        .filter(|&i| {
            plan.bindings[i].stage != agentic_hetero::plan::Stage::Cpu
        })
        .collect();
    llm[rng.index(llm.len())]
}

/// Apply one random single-field mutation; return the diagnostic the
/// analyzer must now report. Mutations that need a specific plan shape
/// (the token-fraction split) draw the mixed-generation preset; the
/// rest mutate whichever preset the case picked.
fn mutate(plan: &mut ExecutionPlan, rng: &mut Rng) -> (&'static str, Severity) {
    match rng.index(15) {
        // --- pass 1: topology ---
        0 => {
            let i = binding_with_deps(plan, rng);
            plan.bindings[i].deps[0] = plan.bindings.len() + 7;
            ("AH001", Severity::Error)
        }
        1 => {
            let i = 1 + rng.index(plan.bindings.len() - 1);
            plan.bindings[i].deps = vec![i];
            ("AH002", Severity::Error)
        }
        2 => {
            let mut orphan = plan.bindings[0].clone();
            orphan.deps.clear();
            plan.bindings.push(orphan);
            ("AH003", Severity::Warn)
        }
        // --- pass 2: binding invariants ---
        3 => {
            *plan = presets::mixed_generation("8b-fp16", "H100", "A100", 2, 2);
            // Break the decode split's partition: 0.9 + 0.5 != 1.
            plan.bindings[2].token_fraction = 0.9;
            ("AH010", Severity::Error)
        }
        4 => {
            let i = rng.index(plan.bindings.len());
            plan.bindings[i].prefix_overlap = if rng.bool(0.5) { 1.5 } else { -0.25 };
            ("AH011", Severity::Error)
        }
        5 => {
            let g = rng.index(plan.pipelines.len());
            match rng.index(4) {
                0 => plan.pipelines[g].tp = 0,
                1 => plan.pipelines[g].pp = 0,
                2 => plan.pipelines[g].max_batch = 0,
                _ => plan.pipelines[g].replicas = 0,
            }
            ("AH012", Severity::Error)
        }
        6 => {
            let i = llm_binding(plan, rng);
            plan.bindings[i].class = "B200".into();
            ("AH013", Severity::Error)
        }
        7 => {
            let g = rng.index(plan.pipelines.len());
            plan.pipelines[g].device = "TPUv9".into();
            ("AH014", Severity::Error)
        }
        8 => {
            let i = rng.index(plan.bindings.len());
            plan.bindings[i].token_fraction =
                [0.0, -0.5, 1.5][rng.index(3)];
            ("AH015", Severity::Error)
        }
        9 => {
            let dup = plan.pipelines[rng.index(plan.pipelines.len())].clone();
            plan.pipelines.push(dup);
            ("AH016", Severity::Warn)
        }
        10 => {
            let mut orphan = plan.pipelines[0].clone();
            orphan.device = "B200".into();
            plan.pipelines.push(orphan);
            ("AH017", Severity::Warn)
        }
        // --- pass 3: capacity ---
        11 => {
            // 70B fp16 weights (140 GB) cannot fit an 80 GB part at
            // tp1 pp1 — every preset group trips the HBM audit.
            plan.model = "70b-fp16".into();
            ("AH020", Severity::Error)
        }
        12 => {
            plan.admission.rate = 1e9;
            ("AH021", Severity::Warn)
        }
        // --- pass 4: fabric ---
        13 => {
            // All presets hand KV across chassis (prefill and decode
            // groups occupy disjoint ranges).
            plan.fabric.scaleout_gbit = 0.0;
            ("AH030", Severity::Error)
        }
        // --- pass 5: SLA ---
        _ => {
            plan.sla = SlaSpec::EndToEnd(1e-4);
            ("AH040", Severity::Warn)
        }
    }
}

#[test]
fn single_field_mutations_are_caught() {
    check("plan-verify-mutations", |rng| {
        let mut all = clean_presets();
        let (name, mut plan) = all.swap_remove(rng.index(all.len()));
        let (code, severity) = mutate(&mut plan, rng);
        let report = verify::verify(&plan);
        assert!(
            report
                .diags
                .iter()
                .any(|d| d.code == code && d.severity == severity),
            "mutated {name} must report {code} ({}):\n{}",
            severity.name(),
            report.table()
        );
        // The loader gate agrees with the report: rejected iff any
        // Error-severity finding.
        assert_eq!(
            verify::ensure_loadable(&plan).is_err(),
            report.has_errors(),
            "ensure_loadable must reject exactly the Error reports"
        );
        // Diagnostics survive the JSON round-trip bit-for-bit.
        let back = DiagReport::from_json(&report.to_json())
            .expect("report json must re-parse");
        assert_eq!(back, report, "diagnostic JSON round-trip must be identity");
    });
}

#[test]
fn extra_chassis_gap_is_warned() {
    // Moving the last group past a hole leaves the fabric with an
    // unoccupied chassis — advisory, not fatal.
    let mut plan = presets::homogeneous("8b-fp16", "H100", 2);
    let last = plan.pipelines.len() - 1;
    plan.pipelines[last].chassis += 10;
    let report = verify::verify(&plan);
    assert!(
        report
            .diags
            .iter()
            .any(|d| d.code == "AH032" && d.severity == Severity::Warn),
        "chassis gap must warn:\n{}",
        report.table()
    );
    assert!(verify::ensure_loadable(&plan).is_ok());
}
