//! API shim for the vendored `xla-rs` crate.
//!
//! The real PJRT runtime (`xla_extension` + the `xla` Rust bindings) is
//! vendored out-of-tree and not available in CI or offline checkouts,
//! which used to mean `rust/src/runtime/engine.rs` was *never even
//! type-checked* — the `pjrt` feature could rot silently. This crate
//! mirrors exactly the slice of the `xla-rs` API surface the engine
//! uses, with every entry point either returning an "unavailable" error
//! or panicking if something manages to call past one, so
//!
//! ```text
//! cargo check --features pjrt
//! ```
//!
//! compile-gates the real engine everywhere. To light up actual PJRT
//! execution, point the `xla` path dependency in the workspace
//! `Cargo.toml` at a real `xla-rs` checkout instead of this shim.

use std::borrow::Borrow;

/// Mirror of `xla::Error` — the engine only ever formats it.
#[derive(Debug)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: xla shim only type-checks the pjrt engine; vendor the \
         real xla-rs crate (see vendor/xla-shim) to execute"
    )))
}

/// Host-side literal (tensor) handle.
#[derive(Debug)]
pub struct Literal {
    _opaque: (),
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal { _opaque: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn decompose_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::decompose_tuple")
    }

    pub fn size_bytes(&self) -> usize {
        0
    }
}

/// Parsed HLO module (text interchange).
#[derive(Debug)]
pub struct HloModuleProto {
    _opaque: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation wrapper handed to `PjRtClient::compile`.
#[derive(Debug)]
pub struct XlaComputation {
    _opaque: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _opaque: () }
    }
}

/// Device-side buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _opaque: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _opaque: (),
}

impl PjRtLoadedExecutable {
    /// Execute over owned or borrowed literals (the engine uses both
    /// `execute::<Literal>` and `execute::<&Literal>`).
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient {
    _opaque: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "xla-shim (PJRT unavailable)".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_unavailable() {
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        assert!(PjRtClient::cpu().is_err());
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert_eq!(lit.size_bytes(), 0);
        assert!(lit.reshape(&[3, 1]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
        assert!(lit.decompose_tuple().is_err());
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("xla shim"));
    }
}
