//! End-to-end serving driver — the full stack on a real model.
//!
//! Loads the AOT-compiled tiny-LLaMA artifacts (built by `make
//! artifacts`: JAX model + Pallas attention kernel lowered to HLO text),
//! brings up the serving coordinator (admission → continuous batcher →
//! PJRT prefill/decode), submits batched requests from concurrent
//! client threads, and reports TTFT / TBT / throughput / SLA
//! attainment. Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_e2e
//! ```

use std::sync::mpsc;
use std::time::{Duration, Instant};

use agentic_hetero::runtime::Engine;
use agentic_hetero::server::{ChatRequest, ChatResponse, Server, ServerConfig};
use agentic_hetero::util::bench::percentile;

const N_CLIENTS: usize = 4;
const REQS_PER_CLIENT: usize = 12;
const MAX_NEW_TOKENS: usize = 24;
const SLA_TTFT_S: f64 = 0.250;
const SLA_TBT_S: f64 = 0.100;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let t_load = Instant::now();
    let engine = Engine::load("artifacts")
        .map_err(|e| format!("{e}\nhint: run `make artifacts` first"))?;
    println!(
        "engine: platform={} model={} params, buckets {:?}, loaded in {:.1}s",
        engine.platform(),
        engine.manifest.num_params,
        engine.manifest.buckets,
        t_load.elapsed().as_secs_f64()
    );

    let mut server = Server::new(engine, ServerConfig::default());
    let metrics = server.metrics.clone();

    // Client side: N threads submitting a Poisson-ish request stream.
    let (req_tx, req_rx) = mpsc::channel::<ChatRequest>();
    let (resp_tx, resp_rx) = mpsc::channel::<ChatResponse>();
    let prompts = [
        "the paper describes the ",
        "heterogeneous systems can ",
        "the cost of serving ",
        "agents are composed of ",
    ];
    let mut clients = Vec::new();
    for c in 0..N_CLIENTS {
        let tx = req_tx.clone();
        clients.push(std::thread::spawn(move || {
            for i in 0..REQS_PER_CLIENT {
                let id = (c * REQS_PER_CLIENT + i) as u64;
                let mut req =
                    ChatRequest::new(id, prompts[(id as usize) % prompts.len()], MAX_NEW_TOKENS);
                req.session = Some(c as u64); // each client is a session
                tx.send(req).unwrap();
                std::thread::sleep(Duration::from_millis(5 + (id % 7) * 3));
            }
        }));
    }
    drop(req_tx);

    // Server side: the engine thread (PJRT client is !Send, so the
    // engine lives here and clients feed it through the channel).
    let t0 = Instant::now();
    server.serve(req_rx, resp_tx)?;
    let wall = t0.elapsed().as_secs_f64();
    for c in clients {
        c.join().unwrap();
    }

    let responses: Vec<ChatResponse> = resp_rx.into_iter().collect();
    let total = N_CLIENTS * REQS_PER_CLIENT;
    assert_eq!(responses.len(), total, "all requests must complete");

    let ttfts: Vec<f64> = responses.iter().map(|r| r.ttft_s).collect();
    let tbts: Vec<f64> = responses
        .iter()
        .filter(|r| r.tbt_mean_s > 0.0)
        .map(|r| r.tbt_mean_s)
        .collect();
    let tokens: usize = responses.iter().map(|r| r.tokens).sum();
    let ttft_ok = ttfts.iter().filter(|t| **t <= SLA_TTFT_S).count();
    let tbt_ok = tbts.iter().filter(|t| **t <= SLA_TBT_S).count();

    println!("\n--- sample outputs (trained byte-LM) ---");
    for r in responses.iter().take(3) {
        println!("#{:>2}: {:?}", r.id, r.text());
    }

    println!("\n--- serving report ---");
    println!("requests            {total}");
    println!("output tokens       {tokens}");
    println!("wall time           {wall:.2}s");
    println!("throughput          {:.0} tok/s", tokens as f64 / wall);
    println!(
        "TTFT   p50 {:>7.1}ms  p95 {:>7.1}ms  (SLA {}ms: {}/{} ok)",
        percentile(&ttfts, 50.0) * 1e3,
        percentile(&ttfts, 95.0) * 1e3,
        SLA_TTFT_S * 1e3,
        ttft_ok,
        total
    );
    if !tbts.is_empty() {
        println!(
            "TBT    p50 {:>7.1}ms  p95 {:>7.1}ms  (SLA {}ms: {}/{} ok)",
            percentile(&tbts, 50.0) * 1e3,
            percentile(&tbts, 95.0) * 1e3,
            SLA_TBT_S * 1e3,
            tbt_ok,
            tbts.len()
        );
    }
    println!("\n--- server metrics ---\n{}", metrics.report());
    Ok(())
}
