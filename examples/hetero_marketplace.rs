//! Heterogeneous fleet "marketplace" study (paper §7.3): given a mixed
//! pool of accelerators with spare capacity, which pairings should a
//! marketplace advertise for each model/SLA, and what is the buyer's
//! TCO benefit vs renting homogeneous H100s?
//!
//! Also demonstrates migration planning: what it takes to move a live
//! deployment from the homogeneous baseline to the marketplace winner.
//!
//! ```bash
//! cargo run --release --example hetero_marketplace
//! ```

use agentic_hetero::agents;
use agentic_hetero::cluster::sim::simulate_plan;
use agentic_hetero::cluster::trace::{generate, TraceConfig};
use agentic_hetero::cost::hardware::catalog;
use agentic_hetero::cost::model_profile::table4;
use agentic_hetero::opt::assignment::Sla;
use agentic_hetero::opt::parallelism::{best_config, ExploreOpts, SeqShape, SlaMode};
use agentic_hetero::planner::migration::{plan_migration, RoleMap};
use agentic_hetero::planner::plan::{Planner, PlannerConfig};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let devices = catalog();
    let opts = ExploreOpts::default();
    let shape = SeqShape { isl: 1024, osl: 1024 };

    println!("marketplace sweep: all {}x{} prefill::decode pairings", devices.len(), devices.len());
    for m in table4() {
        for sla in [SlaMode::paper_latency(), SlaMode::Throughput] {
            // Baseline: homogeneous H100.
            let h100 = devices.iter().find(|d| d.name == "H100").unwrap();
            let Some(base) = best_config(&m, h100, h100, shape, sla, &opts) else {
                continue;
            };
            // Sweep every pairing; keep the frontier of the top 3.
            let mut offers: Vec<(String, f64)> = Vec::new();
            for pd in &devices {
                for dd in &devices {
                    if let Some(cfg) = best_config(&m, pd, dd, shape, sla, &opts) {
                        offers.push((
                            format!("{}::{}", pd.name, dd.name),
                            base.usd_per_mtok / cfg.usd_per_mtok,
                        ));
                    }
                }
            }
            offers.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            println!("\n{} — {}", m.name, sla.name());
            for (pair, benefit) in offers.iter().take(3) {
                println!("  {pair:<18} {benefit:.2}x vs H100::H100");
            }
        }
    }

    // Migration: homogeneous H100 fleet -> the FP8-8B throughput winner.
    println!("\n=== migration plan: H100::H100 -> B200::Gaudi3 ===");
    let mut current = RoleMap::new();
    current.insert(("H100".into(), "prefill".into()), 2);
    current.insert(("H100".into(), "decode".into()), 4);
    let mut target = RoleMap::new();
    target.insert(("B200".into(), "prefill".into()), 1);
    target.insert(("Gaudi3".into(), "decode".into()), 4);
    // Price the KV motion over the same contended fabric the simulator
    // uses: 8 chassis, 400 Gbit RoCE NICs.
    let fabric = agentic_hetero::transport::fabric::Fabric::new(8, 8, 900.0, 400.0);
    let plan = plan_migration(&current, &target, 8e9, &fabric);
    for step in &plan.steps {
        println!("  {step:?}");
    }
    println!(
        "  moves {:.1} GB of KV, est. {:.1}s",
        plan.kv_bytes / 1e9,
        plan.est_duration_s
    );

    // Buyer-side validation: plan a RAG agent on the marketplace fleet
    // and execute its full DAG (embed → vector lookup → assemble →
    // prefill → decode → store) in the cluster simulator via the
    // unified ExecutionPlan.
    println!("\n=== buyer check: RAG agent DAG on the planned fleet ===");
    let rag = agents::rag_agent("8b-fp16", 1024, 128, 8);
    let mut pcfg = PlannerConfig::default();
    pcfg.sla = Sla::EndToEnd(4.0);
    let exec_plan = Planner::new(pcfg).plan(&rag)?;
    println!("  {}", exec_plan.summary());
    let trace = generate(&TraceConfig {
        n_requests: 128,
        rate: 8.0,
        isl_mean: 1024,
        osl_mean: 128,
        sigma: 0.3,
        seed: 21,
    });
    let report = simulate_plan(&exec_plan, &trace)?;
    println!("  {}", report.summary());
    Ok(())
}
