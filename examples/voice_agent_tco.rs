//! Voice-agent TCO study — the paper's §5 evaluation scenario end to
//! end, driven by one serializable ExecutionPlan: plan the Figure-2
//! voice agent across the catalog, round-trip the plan through JSON,
//! and execute the *full agent DAG* (STT → search loop → prefill →
//! decode → TTS) in the discrete-event cluster simulator under
//! increasing load.
//!
//! ```bash
//! cargo run --release --example voice_agent_tco
//! ```

use agentic_hetero::agents;
use agentic_hetero::cluster::sim::simulate_plan;
use agentic_hetero::cluster::trace::{voice_agent as voice_trace, TraceConfig};
use agentic_hetero::cost::hardware::by_name;
use agentic_hetero::cost::model_profile::llama3_8b;
use agentic_hetero::cost::Precision;
use agentic_hetero::opt::assignment::Sla;
use agentic_hetero::opt::parallelism::{best_config, ExploreOpts, SeqShape, SlaMode};
use agentic_hetero::plan::ExecutionPlan;
use agentic_hetero::planner::plan::{Planner, PlannerConfig};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // ---- 1. Plan the agent graph (slow path) -------------------------
    let agent = agents::voice_agent("8b-fp16", 512, 256);
    let mut cfg = PlannerConfig::default();
    cfg.sla = Sla::EndToEnd(3.0);
    let plan = Planner::new(cfg).plan(&agent)?;
    println!("=== graph placement (SLA 3s) ===");
    for (op, class) in plan.placements() {
        println!("  {op:<22} -> {class}");
    }
    println!("  {}", plan.summary());

    // ---- 2. The plan is a durable artifact: JSON round-trip ----------
    let json = plan.to_json_string();
    let replayed = ExecutionPlan::parse_json(&json)?;
    assert_eq!(replayed, plan, "plan must survive save/replay");
    println!("\nplan JSON: {} bytes, round-trips losslessly", json.len());

    // ---- 3. Size the LLM stages: which prefill::decode pair? ---------
    let m = llama3_8b(Precision::Fp16);
    let opts = ExploreOpts::default();
    let shape = SeqShape { isl: 512, osl: 256 };
    println!("\n=== disaggregated LLM config search (tokens/s/$) ===");
    let mut best: Option<(String, f64)> = None;
    for (p, d) in [
        ("H100", "H100"),
        ("H100", "Gaudi3"),
        ("B200", "Gaudi3"),
        ("Gaudi3", "Gaudi3"),
        ("H100", "A100"),
    ] {
        let (pd, dd) = (by_name(p).unwrap(), by_name(d).unwrap());
        if let Some(cfg) = best_config(&m, &pd, &dd, shape, SlaMode::paper_latency(), &opts)
        {
            println!(
                "  {p:>7}::{d:<7} ${:>6.3}/Mtok  ttft {:>5.0}ms  tbt {:>5.1}ms  (p tp{} b{} | d tp{} b{})",
                cfg.usd_per_mtok,
                cfg.ttft_s * 1e3,
                cfg.tbt_s * 1e3,
                cfg.prefill.par.tp,
                cfg.prefill.batch,
                cfg.decode.par.tp,
                cfg.decode.batch
            );
            if best.as_ref().map(|(_, c)| cfg.usd_per_mtok < *c).unwrap_or(true) {
                best = Some((format!("{p}::{d}"), cfg.usd_per_mtok));
            }
        }
    }
    let (best_pair, best_cost) = best.expect("some pair feasible");
    println!("  -> winner: {best_pair} at ${best_cost:.3}/Mtok");

    // ---- 4. Execute the planned agent DAG under rising load ----------
    // The same replayed plan drives the simulator: CPU stages (STT,
    // search loop, TTS) on the worker pool, prefill/decode on the
    // planned pipelines, KV handoffs over the fabric.
    println!("\n=== agent-DAG simulation of the plan ===");
    for rate in [2.0, 8.0, 16.0] {
        let trace = voice_trace(&TraceConfig {
            n_requests: 192,
            rate,
            isl_mean: 512,
            osl_mean: 256,
            sigma: 0.3,
            seed: 7,
        });
        let report = simulate_plan(&replayed, &trace)?;
        println!("  rate {rate:>4.0} req/s: {}", report.summary());
    }

    println!(
        "\nTakeaway: one ExecutionPlan pins STT/TTS/tools to CPUs, \
         disaggregates the LLM across heterogeneous pipelines, survives a \
         JSON round-trip, and sustains the voice-agent SLA in full-DAG \
         simulation."
    );
    Ok(())
}
