//! Voice-agent TCO study — the paper's §5 evaluation scenario end to
//! end: plan the Figure-2 voice agent across the catalog, then validate
//! the chosen disaggregated placement in the discrete-event cluster
//! simulator under increasing load.
//!
//! ```bash
//! cargo run --release --example voice_agent_tco
//! ```

use agentic_hetero::agents;
use agentic_hetero::cluster::sim::{pair_placement, ClusterSim};
use agentic_hetero::cluster::trace::{voice_agent as voice_trace, TraceConfig};
use agentic_hetero::cost::hardware::by_name;
use agentic_hetero::cost::model_profile::llama3_8b;
use agentic_hetero::cost::roofline::Parallelism;
use agentic_hetero::cost::Precision;
use agentic_hetero::opt::assignment::Sla;
use agentic_hetero::opt::parallelism::{best_config, ExploreOpts, SeqShape, SlaMode};
use agentic_hetero::planner::plan::{Planner, PlannerConfig};
use agentic_hetero::transport::fabric::Fabric;

fn main() -> anyhow::Result<()> {
    // ---- 1. Plan the agent graph (slow path) -------------------------
    let agent = agents::voice_agent("8b-fp16", 512, 256);
    let mut cfg = PlannerConfig::default();
    cfg.sla = Sla::EndToEnd(3.0);
    let plan = Planner::new(cfg).plan(&agent)?;
    println!("=== graph placement (SLA 3s) ===");
    for (op, class) in &plan.placements {
        println!("  {op:<22} -> {class}");
    }

    // ---- 2. Size the LLM stages: which prefill::decode pair? ---------
    let m = llama3_8b(Precision::Fp16);
    let opts = ExploreOpts::default();
    let shape = SeqShape { isl: 512, osl: 256 };
    println!("\n=== disaggregated LLM config search (tokens/s/$) ===");
    let mut best: Option<(String, f64)> = None;
    for (p, d) in [
        ("H100", "H100"),
        ("H100", "Gaudi3"),
        ("B200", "Gaudi3"),
        ("Gaudi3", "Gaudi3"),
        ("H100", "A100"),
    ] {
        let (pd, dd) = (by_name(p).unwrap(), by_name(d).unwrap());
        if let Some(cfg) = best_config(&m, &pd, &dd, shape, SlaMode::paper_latency(), &opts)
        {
            println!(
                "  {p:>7}::{d:<7} ${:>6.3}/Mtok  ttft {:>5.0}ms  tbt {:>5.1}ms  (p tp{} b{} | d tp{} b{})",
                cfg.usd_per_mtok,
                cfg.ttft_s * 1e3,
                cfg.tbt_s * 1e3,
                cfg.prefill.par.tp,
                cfg.prefill.batch,
                cfg.decode.par.tp,
                cfg.decode.batch
            );
            if best.as_ref().map(|(_, c)| cfg.usd_per_mtok < *c).unwrap_or(true) {
                best = Some((format!("{p}::{d}"), cfg.usd_per_mtok));
            }
        }
    }
    let (best_pair, best_cost) = best.expect("some pair feasible");
    println!("  -> winner: {best_pair} at ${best_cost:.3}/Mtok");

    // ---- 3. Validate in the cluster simulator under rising load ------
    println!("\n=== simulator validation (H100 prefill :: Gaudi3 decode) ===");
    let h100 = by_name("H100").unwrap();
    let gaudi = by_name("Gaudi3").unwrap();
    for rate in [2.0, 8.0, 16.0] {
        let placement = pair_placement(
            &h100,
            Parallelism { tp: 1, pp: 1 },
            1,
            8,
            &gaudi,
            Parallelism { tp: 1, pp: 1 },
            2,
            64,
        );
        let fabric = Fabric::new(4, 8, h100.scaleup_bw_gbps, 400.0);
        let mut sim = ClusterSim::new(llama3_8b(Precision::Fp16), placement, fabric);
        let trace = voice_trace(&TraceConfig {
            n_requests: 192,
            rate,
            isl_mean: 512,
            osl_mean: 256,
            sigma: 0.3,
            seed: 7,
        });
        let report = sim.run(&trace)?;
        println!("  rate {rate:>4.0} req/s: {}", report.summary());
    }

    println!(
        "\nTakeaway: the planner pins STT/TTS/tools to CPUs, disaggregates the \
         LLM, and the heterogeneous pair sustains the voice-agent SLA at a \
         lower $/Mtok than the homogeneous H100 baseline."
    );
    Ok(())
}
