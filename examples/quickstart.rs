//! Quickstart: author an agent graph, lower it through the IR pipeline,
//! and let the cost-aware planner place it on heterogeneous hardware.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use agentic_hetero::agents;
use agentic_hetero::ir::passes::PassManager;
use agentic_hetero::ir::printer;
use agentic_hetero::opt::assignment::Sla;
use agentic_hetero::planner::plan::{Planner, PlannerConfig};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Author an agent — the paper's Figure-2 conversational voice
    //    agent with an 8B FP16 LLM, 512-token prompts, 256-token replies.
    let agent = agents::voice_agent("8b-fp16", 512, 256);
    println!("=== authored agent graph ===\n{}", printer::print(&agent));

    // 2. Lower it: decompose the LLM into prefill/decode, split tools,
    //    fuse CPU stages, annotate every node with cost vectors.
    let mut lowered = agent.clone();
    let mut pm = PassManager::standard();
    pm.run(&mut lowered)?;
    println!("=== lowered (decomposed + annotated) ===");
    for (pass, changed) in &pm.log {
        println!("  pass {pass:<18} {}", if *changed { "changed" } else { "-" });
    }

    // 3. Plan: assign every node to a hardware class under a 2-second
    //    end-to-end SLA, minimizing $ per request.
    let mut cfg = PlannerConfig::default();
    cfg.sla = Sla::EndToEnd(2.0);
    let planner = Planner::new(cfg);
    let plan = planner.plan(&agent)?;

    println!("\n=== placement (SLA 2s) ===");
    for (op, class) in plan.placements() {
        println!("  {op:<22} -> {class}");
    }
    println!(
        "\ncost ${:.6}/request, critical path {:.0} ms",
        plan.cost_usd,
        plan.latency_s * 1e3
    );

    // 4. The §5.3 takeaway reproduced: non-LLM stages on CPU, LLM stages
    //    on (possibly different!) accelerators.
    assert_eq!(plan.class_of("stt.transcribe"), Some("CPU"));
    assert_ne!(plan.class_of("llm.prefill").unwrap(), "CPU");
    println!("\nOK: non-LLM stages on CPU, LLM stages on accelerators.");
    Ok(())
}
